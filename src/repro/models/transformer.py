"""Decoder-only LM assembling the block zoo (attn/MoE/SSM/RG-LRU).

One class serves the dense, moe, ssm, hybrid and vlm families. Layers are
scanned (homogeneous stacks -> O(1) HLO in depth) with configurable remat.
Hybrid archs scan over repeating pattern *cycles* with an unrolled
remainder. Decode threads per-layer caches through the scan as ys.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, lshard
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import mamba2, moe, rglru
from repro.models.spec import (P, abstract_params, axes_tree, init_params,
                               stack_tree, tree_map_specs)


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=pol)
    return jax.checkpoint(fn)  # 'full': save nothing


class DecodeState(NamedTuple):
    """Unified per-arch decode cache."""
    kv: Optional[attn.KVCache]          # attn layers (stacked)
    conv: Optional[jax.Array]           # ssm/rglru conv states (stacked)
    rec: Optional[jax.Array]            # ssm state / rglru hidden (stacked)
    index: jax.Array                    # next absolute position (scalar)


class LM:
    """Unified decoder-only language model."""

    def __init__(self, cfg, attn_impl: str = "chunked"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.kinds = cfg.layer_kinds()

    # ------------------------------------------------------------------
    # Parameter specs
    # ------------------------------------------------------------------
    def _block_specs(self, kind: str) -> dict:
        cfg = self.cfg
        s: Dict[str, Any] = {"norm1": L.norm_spec(cfg, cfg.d_model)}
        if kind == "attn":
            s["attn"] = attn.attn_specs(cfg)
            s["norm2"] = L.norm_spec(cfg, cfg.d_model)
            if cfg.is_moe:
                s["moe"] = moe.moe_specs(cfg)
            else:
                s["mlp"] = L.mlp_specs(cfg.d_model, cfg.d_ff)
        elif kind == "ssm":
            s["ssm"] = mamba2.mamba_specs(cfg)
        elif kind == "rglru":
            s["rglru"] = rglru.rglru_specs(cfg)
            s["norm2"] = L.norm_spec(cfg, cfg.d_model)
            s["mlp"] = L.mlp_specs(cfg.d_model, cfg.d_ff)
        else:
            raise ValueError(kind)
        return s

    def specs(self) -> dict:
        cfg = self.cfg
        out: Dict[str, Any] = {"embed": L.embed_specs(cfg),
                               "final_norm": L.norm_spec(cfg, cfg.d_model)}
        if cfg.block_pattern:
            pat = cfg.block_pattern
            nc, rest = divmod(cfg.num_layers, len(pat))
            cyc = {f"slot{i}": stack_tree(self._block_specs(k), nc)
                   for i, k in enumerate(pat)}
            out["cycles"] = cyc
            for i in range(rest):
                out[f"rest{i}"] = self._block_specs(pat[i])
        else:
            out["layers"] = stack_tree(self._block_specs(self.kinds[0]),
                                       cfg.num_layers)
        return out

    def init(self, rng: jax.Array):
        return init_params(self.specs(), rng, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.specs(), self.cfg.param_dtype)

    def param_axes(self):
        return axes_tree(self.specs())

    # ------------------------------------------------------------------
    # Blocks (full sequence)
    # ------------------------------------------------------------------
    def _apply_block(self, kind: str, p: dict, x, positions, aux,
                     collect_cache: bool = False):
        cfg = self.cfg
        cache = None
        h = L.norm_apply(cfg, x, p["norm1"])
        if kind == "attn":
            window = cfg.sliding_window if cfg.family != "hybrid" else cfg.local_attn_window
            o, kv = attn.attn_apply(cfg, p["attn"], h, positions=positions,
                                    causal=True, window=window,
                                    impl=self.attn_impl,
                                    kv_for_cache=collect_cache)
            x = x + o * cfg.residual_multiplier
            h2 = L.norm_apply(cfg, x, p["norm2"])
            if cfg.is_moe:
                o2, a = moe.moe_apply(cfg, p["moe"], h2, mesh=current_mesh())
                aux = aux + a
            else:
                o2 = L.mlp_apply(cfg, p["mlp"], h2)
            x = x + o2 * cfg.residual_multiplier
            cache = kv
        elif kind == "ssm":
            o, st = mamba2.mamba_apply(cfg, p["ssm"], h,
                                       return_state=collect_cache)
            x = x + o
            cache = st
        elif kind == "rglru":
            o, st = rglru.rglru_apply(cfg, p["rglru"], h,
                                      return_state=collect_cache)
            x = x + o
            h2 = L.norm_apply(cfg, x, p["norm2"])
            x = x + L.mlp_apply(cfg, p["mlp"], h2)
            cache = st
        # sequence-parallel residual annotation (no-op unless the
        # 'residual_seq' rule maps to a mesh axis — see §Perf)
        x = lshard(x, "batch", "residual_seq", "act_embed")
        return x, aux, cache

    # ------------------------------------------------------------------
    # Forward (train / prefill trunk)
    # ------------------------------------------------------------------
    def hidden(self, params, tokens, *, collect_cache: bool = False):
        """tokens [B,S] -> hidden [B,S,D], aux, caches(list per layer-group)."""
        cfg = self.cfg
        B, S = tokens.shape
        x = L.embed_tokens(cfg, params["embed"], tokens)
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        aux0 = jnp.zeros((), jnp.float32)
        caches: Dict[str, Any] = {}

        if cfg.block_pattern:
            pat = cfg.block_pattern

            def cycle_body(carry, pc):
                x, aux = carry
                cs = []
                for i, k in enumerate(pat):
                    x, aux, c = self._apply_block(k, pc[f"slot{i}"], x,
                                                  positions, aux,
                                                  collect_cache)
                    cs.append(c)
                return (x, aux), tuple(cs)

            body = _remat(cfg, cycle_body)
            (x, aux), cyc_caches = jax.lax.scan(body, (x, aux0),
                                                params["cycles"])
            caches["cycles"] = cyc_caches
            i = 0
            while f"rest{i}" in params:
                x, aux, c = self._apply_block(pat[i], params[f"rest{i}"], x,
                                              positions, aux, collect_cache)
                caches[f"rest{i}"] = c
                i += 1
        else:
            kind = self.kinds[0]

            def body(carry, pl):
                x, aux = carry
                x, aux, c = self._apply_block(kind, pl, x, positions, aux,
                                              collect_cache)
                return (x, aux), c

            (x, aux), layer_caches = jax.lax.scan(_remat(cfg, body),
                                                  (x, aux0),
                                                  params["layers"])
            caches["layers"] = layer_caches

        x = L.norm_apply(cfg, x, params["final_norm"])
        return x, aux, caches

    def apply(self, params, tokens):
        x, aux, _ = self.hidden(params, tokens)
        return L.logits_from_hidden(self.cfg, params["embed"], x), aux

    def loss(self, params, batch):
        # apply on the FULL sequence (keeps chunked-attention divisibility;
        # shifting inputs to S-1 would silently fall back to quadratic
        # attention) and drop the last position's logits instead.
        tokens = batch["tokens"]
        logits, aux = self.apply(params, tokens)
        logits = logits[:, :-1]
        labels = tokens[:, 1:]
        mask = batch.get("mask")
        mask = mask[:, 1:] if mask is not None else None
        ce = L.cross_entropy(logits, labels, mask)
        coef = self.cfg.moe.router_aux_coef if self.cfg.is_moe else 0.0
        nl = max(1, sum(1 for k in self.kinds if k == "attn"))
        return ce + coef * aux / nl, {"ce": ce, "aux": aux / nl}

    # ------------------------------------------------------------------
    # Decode caches
    # ------------------------------------------------------------------
    def _attn_window(self) -> Optional[int]:
        cfg = self.cfg
        if cfg.family == "hybrid":
            return cfg.local_attn_window
        return cfg.sliding_window

    def _counts(self) -> Dict[str, int]:
        c: Dict[str, int] = {}
        for k in self.kinds:
            c[k] = c.get(k, 0) + 1
        return c

    def init_cache(self, batch: int, max_len: int) -> DecodeState:
        cfg = self.cfg
        counts = self._counts()
        dt = jnp.dtype(cfg.dtype)
        kv = conv = rec = None
        if counts.get("attn"):
            kv = attn.init_kv_cache(cfg, counts["attn"], batch, max_len,
                                    window=self._attn_window(), dtype=dt)
        if counts.get("ssm"):
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            nheads = d_in // s.head_dim
            cc = d_in + 2 * s.n_groups * s.state_dim
            conv = jnp.zeros((counts["ssm"], batch, s.conv_dim - 1, cc), dt)
            rec = jnp.zeros((counts["ssm"], batch, nheads, s.head_dim,
                             s.state_dim), jnp.float32)
        if counts.get("rglru"):
            w = cfg.rglru_width or cfg.d_model
            conv = jnp.zeros((counts["rglru"], batch, 3, w), dt)
            rec = jnp.zeros((counts["rglru"], batch, w), jnp.float32)
        return DecodeState(kv, conv, rec, jnp.zeros((), jnp.int32))

    def cache_axes(self) -> DecodeState:
        counts = self._counts()
        kv = attn.cache_axes(self.cfg) if counts.get("attn") else None
        conv = rec = None
        if counts.get("ssm"):
            ax = mamba2.mamba_cache_axes()
            conv, rec = ax.conv, ax.state
        if counts.get("rglru"):
            ax = rglru.rglru_cache_axes()
            conv, rec = ax.conv, ax.h
        return DecodeState(kv, conv, rec, ())

    # ------------------------------------------------------------------
    # Prefill
    # ------------------------------------------------------------------
    def prefill(self, params, tokens,
                max_len: Optional[int] = None) -> Tuple[jax.Array, DecodeState]:
        cfg = self.cfg
        B, S = tokens.shape
        max_len = max_len or S
        x, _, caches = self.hidden(params, tokens, collect_cache=True)
        logits = L.logits_from_hidden(cfg, params["embed"], x[:, -1:, :])

        # Flatten collected per-layer caches into DecodeState stacks.
        # Layout: attn kv stacked in layer order [n_attn, B, ...];
        # recurrent states flat [n_rec, B, ...] — for hybrids the cycle part
        # is ordered (cycle0.slot0, cycle0.slot1, cycle1.slot0, ...) i.e.
        # reshaped from [nc, slots_per_cycle, ...], remainder appended.
        kv = conv = rec = None
        W = self._attn_window()

        if cfg.block_pattern:
            pat = cfg.block_pattern
            cyc = caches["cycles"]
            kv_parts = [cyc[i] for i, k in enumerate(pat) if k == "attn"]
            rec_parts = [cyc[i] for i, k in enumerate(pat) if k != "attn"]
            kv_k = [c[0] for c in kv_parts]
            kv_v = [c[1] for c in kv_parts]
            convs, recs = None, None
            if rec_parts:
                # [nc, slots, B, ...] -> [nc*slots, B, ...] (layer order)
                cv = jnp.stack([c[0] for c in rec_parts], axis=1)
                st = jnp.stack([c[1] for c in rec_parts], axis=1)
                convs = cv.reshape((-1,) + cv.shape[2:])
                recs = st.reshape((-1,) + st.shape[2:])
            i = 0
            while f"rest{i}" in caches:
                c = caches[f"rest{i}"]
                if pat[i] == "attn":
                    kv_k.append(c[0][None])
                    kv_v.append(c[1][None])
                else:
                    convs = jnp.concatenate([convs, c[0][None]], axis=0)
                    recs = jnp.concatenate([recs, c[1][None]], axis=0)
                i += 1
            k = jnp.concatenate(kv_k, axis=0) if kv_k else None
            v = jnp.concatenate(kv_v, axis=0) if kv_k else None
            conv, rec = convs, recs
        else:
            c = caches["layers"]
            if self.kinds[0] == "attn":
                k, v = c
            else:
                k = v = None
                conv, rec = c

        if k is not None:
            if W is not None and W < S:
                idx = jnp.arange(S - W, S) % W
                kbuf = jnp.zeros(k.shape[:2] + (W,) + k.shape[3:], k.dtype)
                vbuf = jnp.zeros_like(kbuf)
                kbuf = kbuf.at[:, :, idx].set(k[:, :, -W:])
                vbuf = vbuf.at[:, :, idx].set(v[:, :, -W:])
                k, v = kbuf, vbuf
            else:
                pad = (W if W is not None else max_len) - S
                if pad > 0:
                    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            kv = attn.KVCache(k, v, jnp.asarray(S, jnp.int32))
        return logits, DecodeState(kv, conv, rec, jnp.asarray(S, jnp.int32))

    # ------------------------------------------------------------------
    # Decode
    # ------------------------------------------------------------------
    def decode_step(self, params, state: DecodeState, tokens):
        """tokens [B,1] -> (logits [B,1,V], new state)."""
        cfg = self.cfg
        x = L.embed_tokens(cfg, params["embed"], tokens)
        index = state.index
        W = self._attn_window()

        def attn_step(p, x, kc, vc):
            h = L.norm_apply(cfg, x, p["norm1"])
            o, kc, vc = attn.attn_decode_apply(cfg, p["attn"], h, kc, vc,
                                               index, window=W)
            x = x + o * cfg.residual_multiplier
            h2 = L.norm_apply(cfg, x, p["norm2"])
            if cfg.is_moe:
                o2, _ = moe.moe_apply(cfg, p["moe"], h2, mesh=current_mesh())
            else:
                o2 = L.mlp_apply(cfg, p["mlp"], h2)
            return x + o2 * cfg.residual_multiplier, kc, vc

        def ssm_step(p, x, cv, st):
            h = L.norm_apply(cfg, x, p["norm1"])
            o, (cv, st) = mamba2.mamba_decode_step(cfg, p["ssm"], h, cv, st)
            return x + o, cv, st

        def rglru_step(p, x, cv, st):
            h = L.norm_apply(cfg, x, p["norm1"])
            o, (cv, st) = rglru.rglru_decode_step(cfg, p["rglru"], h, cv, st)
            x = x + o
            h2 = L.norm_apply(cfg, x, p["norm2"])
            return x + L.mlp_apply(cfg, p["mlp"], h2), cv, st

        kv, conv, rec = state.kv, state.conv, state.rec
        if cfg.block_pattern:
            pat = cfg.block_pattern
            n_rec_slots = sum(1 for k in pat if k != "attn")
            nc = self.cfg.num_layers // len(pat)
            n_rest = self.cfg.num_layers - nc * len(pat)
            # split flat recurrent stacks into cycle part + remainder
            cv_cyc = conv[:nc * n_rec_slots].reshape(
                (nc, n_rec_slots) + conv.shape[1:])
            st_cyc = rec[:nc * n_rec_slots].reshape(
                (nc, n_rec_slots) + rec.shape[1:])
            cv_rest, st_rest = conv[nc * n_rec_slots:], rec[nc * n_rec_slots:]

            def cycle_body(x, xs):
                pc, kc, vc, cv, st = xs
                new_k, new_v = kc, vc
                new_cv, new_st = list(cv), list(st)
                r = 0
                for i, kind in enumerate(pat):
                    p = pc[f"slot{i}"]
                    if kind == "attn":
                        x, new_k, new_v = attn_step(p, x, kc, vc)
                    elif kind == "rglru":
                        x, new_cv[r], new_st[r] = rglru_step(p, x, cv[r], st[r])
                        r += 1
                    else:
                        x, new_cv[r], new_st[r] = ssm_step(p, x, cv[r], st[r])
                        r += 1
                return x, (new_k, new_v, jnp.stack(new_cv), jnp.stack(new_st))

            x, (nk, nv, ncv, nst) = jax.lax.scan(
                cycle_body, x,
                (params["cycles"], kv.k, kv.v, cv_cyc, st_cyc))
            kv = attn.KVCache(nk, nv, kv.index)
            for i in range(n_rest):
                kind = pat[i]
                p = params[f"rest{i}"]
                if kind == "attn":  # pragma: no cover (no such arch in pool)
                    raise NotImplementedError("attn remainder layers")
                step = rglru_step if kind == "rglru" else ssm_step
                cv_i, st_i = cv_rest[i], st_rest[i]
                x, cv_i, st_i = step(p, x, cv_i, st_i)
                cv_rest = cv_rest.at[i].set(cv_i)
                st_rest = st_rest.at[i].set(st_i)
            conv = jnp.concatenate(
                [ncv.reshape((-1,) + ncv.shape[2:]), cv_rest], axis=0)
            rec = jnp.concatenate(
                [nst.reshape((-1,) + nst.shape[2:]), st_rest], axis=0)
        else:
            kind = self.kinds[0]
            if kind == "attn":
                def body(x, xs):
                    pl, kc, vc = xs
                    x, kc, vc = attn_step(pl, x, kc, vc)
                    return x, (kc, vc)
                x, (nk, nv) = jax.lax.scan(body, x,
                                           (params["layers"], kv.k, kv.v))
                kv = attn.KVCache(nk, nv, kv.index)
            else:
                def body(x, xs):
                    pl, cv, st = xs
                    x, cv, st = ssm_step(pl, x, cv, st)
                    return x, (cv, st)
                x, (conv, rec) = jax.lax.scan(body, x,
                                              (params["layers"], conv, rec))

        x = L.norm_apply(cfg, x, params["final_norm"])
        logits = L.logits_from_hidden(cfg, params["embed"], x)
        new_index = index + 1
        if kv is not None:
            kv = attn.KVCache(kv.k, kv.v, new_index)
        return logits, DecodeState(kv, conv, rec, new_index)
