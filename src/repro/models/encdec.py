"""Encoder-decoder backbone (Whisper-medium). Conv frontend is a STUB:
the encoder consumes precomputed frame embeddings [B, S_enc, D] from
``input_specs()``. Decoder = causal self-attn + cross-attn + gated MLP.
Assigned seq_len is the total context budget, split (enc, dec) = (S/2, S/2).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models.spec import P, abstract_params, axes_tree, init_params, stack_tree


class EncDecState(NamedTuple):
    self_kv: attn.KVCache          # [L_dec, B, S_dec_max, Hkv, hd]
    cross_k: jax.Array             # [L_dec, B, S_enc, Hkv, hd]
    cross_v: jax.Array
    index: jax.Array


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


class EncDecModel:
    def __init__(self, cfg, attn_impl: str = "chunked"):
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.attn_impl = attn_impl

    # ------------------------------------------------------------------
    def _enc_layer_specs(self) -> dict:
        cfg = self.cfg
        return {"norm1": L.norm_spec(cfg, cfg.d_model),
                "attn": attn.attn_specs(cfg),
                "norm2": L.norm_spec(cfg, cfg.d_model),
                "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff)}

    def _dec_layer_specs(self) -> dict:
        cfg = self.cfg
        return {"norm1": L.norm_spec(cfg, cfg.d_model),
                "self_attn": attn.attn_specs(cfg),
                "norm_x": L.norm_spec(cfg, cfg.d_model),
                "cross_attn": attn.attn_specs(cfg),
                "norm2": L.norm_spec(cfg, cfg.d_model),
                "mlp": L.mlp_specs(cfg.d_model, cfg.d_ff)}

    def specs(self) -> dict:
        cfg = self.cfg
        return {
            "embed": L.embed_specs(cfg),
            "enc_proj": P((cfg.d_model, cfg.d_model), ("embed", "act_embed")),
            "enc_layers": stack_tree(self._enc_layer_specs(),
                                     cfg.num_encoder_layers),
            "enc_norm": L.norm_spec(cfg, cfg.d_model),
            "dec_layers": stack_tree(self._dec_layer_specs(), cfg.num_layers),
            "final_norm": L.norm_spec(cfg, cfg.d_model),
        }

    def init(self, rng):
        return init_params(self.specs(), rng, self.cfg.param_dtype)

    def abstract(self):
        return abstract_params(self.specs(), self.cfg.param_dtype)

    def param_axes(self):
        return axes_tree(self.specs())

    # ------------------------------------------------------------------
    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = jnp.einsum("bsd,de->bse", frames.astype(dt),
                       params["enc_proj"].astype(dt))
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, pl):
            h = L.norm_apply(cfg, x, pl["norm1"])
            o, _ = attn.attn_apply(cfg, pl["attn"], h, positions=positions,
                                   causal=False, impl=self.attn_impl)
            x = x + o
            h2 = L.norm_apply(cfg, x, pl["norm2"])
            return x + L.mlp_apply(cfg, pl["mlp"], h2), None

        x, _ = jax.lax.scan(_remat(cfg, body), x, params["enc_layers"])
        return L.norm_apply(cfg, x, params["enc_norm"])

    def _decode_trunk(self, params, tokens, enc_out, *, collect: bool):
        cfg = self.cfg
        x = L.embed_tokens(cfg, params["embed"], tokens)
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(x, pl):
            h = L.norm_apply(cfg, x, pl["norm1"])
            o, kv = attn.attn_apply(cfg, pl["self_attn"], h,
                                    positions=positions, causal=True,
                                    impl=self.attn_impl,
                                    kv_for_cache=collect)
            x = x + o
            hx = L.norm_apply(cfg, x, pl["norm_x"])
            o2, ckv = self._cross(pl["cross_attn"], hx, enc_out,
                                  collect=collect)
            x = x + o2
            h2 = L.norm_apply(cfg, x, pl["norm2"])
            x = x + L.mlp_apply(cfg, pl["mlp"], h2)
            return x, (kv, ckv)

        x, caches = jax.lax.scan(_remat(cfg, body), x, params["dec_layers"])
        x = L.norm_apply(cfg, x, params["final_norm"])
        return x, caches

    def _cross(self, p, xq, enc_out, *, collect: bool):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        q = jnp.einsum("bsd,dhk->bshk", xq, p["wq"].astype(dt))
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
        o = attn.chunked_attention(q, k, v, causal=False) \
            if self.attn_impl != "naive" else \
            attn.naive_attention(q, k, v, causal=False)
        out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
        return out, ((k, v) if collect else None)

    # ------------------------------------------------------------------
    def apply(self, params, batch: Dict[str, jax.Array]):
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decode_trunk(params, batch["tokens"], enc_out,
                                  collect=False)
        return L.logits_from_hidden(self.cfg, params["embed"], x), jnp.zeros((), jnp.float32)

    def loss(self, params, batch):
        # full-length decode trunk (keeps chunked-attention divisibility);
        # drop the final position's logits instead of shifting inputs.
        enc_out = self.encode(params, batch["frames"])
        toks = batch["tokens"]
        x, _ = self._decode_trunk(params, toks, enc_out, collect=False)
        logits = L.logits_from_hidden(self.cfg, params["embed"], x)[:, :-1]
        ce = L.cross_entropy(logits, toks[:, 1:], batch.get("mask"))
        return ce, {"ce": ce}

    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int) -> EncDecState:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        S_dec = max_len // 2
        S_enc = max_len - S_dec
        hd = cfg.resolved_head_dim
        kv = attn.init_kv_cache(cfg, cfg.num_layers, batch, S_dec, dtype=dt)
        ck = jnp.zeros((cfg.num_layers, batch, S_enc, cfg.num_kv_heads, hd), dt)
        return EncDecState(kv, ck, jnp.zeros_like(ck), jnp.zeros((), jnp.int32))

    def cache_axes(self) -> EncDecState:
        kv = attn.cache_axes(self.cfg)
        cax = ("layers", "batch", "cache_seq", "act_kv_heads", "head_dim")
        return EncDecState(kv, cax, cax, ())

    def prefill(self, params, batch,
                max_len: Optional[int] = None) -> Tuple[jax.Array, EncDecState]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        toks = batch["tokens"]
        x, caches = self._decode_trunk(params, toks, enc_out, collect=True)
        logits = L.logits_from_hidden(cfg, params["embed"], x[:, -1:, :])
        (k, v), (ck, cv) = caches
        S = toks.shape[1]
        pad = (max_len or S) - S
        if pad > 0:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        kv = attn.KVCache(k, v, jnp.asarray(S, jnp.int32))
        return logits, EncDecState(kv, ck, cv, jnp.asarray(S, jnp.int32))

    def decode_step(self, params, state: EncDecState, tokens):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        x = L.embed_tokens(cfg, params["embed"], tokens)
        index = state.index

        def body(x, xs):
            pl, kc, vc, ck, cv = xs
            h = L.norm_apply(cfg, x, pl["norm1"])
            o, kc, vc = attn.attn_decode_apply(cfg, pl["self_attn"], h, kc,
                                               vc, index)
            x = x + o
            hx = L.norm_apply(cfg, x, pl["norm_x"])
            p = pl["cross_attn"]
            q = jnp.einsum("bsd,dhk->bshk", hx, p["wq"].astype(dt))
            o2 = attn.decode_attention(q, ck, cv, jnp.asarray(ck.shape[1] - 1))
            x = x + jnp.einsum("bshk,hkd->bsd", o2, p["wo"].astype(dt))
            h2 = L.norm_apply(cfg, x, pl["norm2"])
            x = x + L.mlp_apply(cfg, pl["mlp"], h2)
            return x, (kc, vc)

        kv = state.self_kv
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["dec_layers"], kv.k, kv.v,
                      state.cross_k, state.cross_v))
        x = L.norm_apply(cfg, x, params["final_norm"])
        logits = L.logits_from_hidden(cfg, params["embed"], x)
        new_kv = attn.KVCache(nk, nv, index + 1)
        return logits, EncDecState(new_kv, state.cross_k, state.cross_v,
                                   index + 1)
