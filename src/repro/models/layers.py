"""Common building blocks: norms, RoPE, gated MLPs, embeddings.

Functional style: params are plain dict pytrees produced from spec trees
(`repro.models.spec`). All blocks annotate activations with logical axes
via `lshard` so the same code runs single-device (no-op) and on the
production mesh (GSPMD constraints).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models.spec import P


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm_spec(d: int) -> P:
    return P((d,), ("act_embed",), init="zeros")  # stored as delta from 1


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
            one_plus: bool = True) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if one_plus else w.astype(jnp.float32)
    return (y * scale).astype(dt)


def layernorm_spec(d: int) -> dict:
    return {"w": P((d,), ("act_embed",), init="zeros"),
            "b": P((d,), ("act_embed",), init="zeros")}


def layernorm(x: jax.Array, p: dict, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + p["w"].astype(jnp.float32)) + p["b"].astype(jnp.float32)).astype(dt)


def norm_apply(cfg, x: jax.Array, p) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p, cfg.norm_eps)
    return rmsnorm(x, p, cfg.norm_eps, one_plus=cfg.rmsnorm_one_plus or True)


def norm_spec(cfg, d: int):
    return layernorm_spec(d) if cfg.norm == "layernorm" else rmsnorm_spec(d)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponents)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]  # broadcast over heads: [..., S, 1, D/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_specs(d: int, ff: int) -> dict:
    return {
        "wi": P((d, ff), ("embed", "mlp")),
        "wg": P((d, ff), ("embed", "mlp")),
        "wo": P((ff, d), ("mlp", "embed")),
    }


def mlp_apply(cfg, p: dict, x: jax.Array) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    act = jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu
    h = jnp.einsum("...d,df->...f", x, p["wi"].astype(dt))
    g = jnp.einsum("...d,df->...f", x, p["wg"].astype(dt))
    h = (act(g.astype(jnp.float32)).astype(dt)) * h
    h = lshard(h, *(("batch",) + ("seq",) * (h.ndim - 2) + ("act_mlp",)))
    out = jnp.einsum("...f,fd->...d", h, p["wo"].astype(dt))
    return out


# ---------------------------------------------------------------------------
# Embedding / logits
# ---------------------------------------------------------------------------

def embed_specs(cfg) -> dict:
    V = cfg.padded_vocab
    d = {"embedding": P((V, cfg.d_model), ("vocab", "embed"), init="embed")}
    if not cfg.tie_embeddings:
        d["unembed"] = P((cfg.d_model, V), ("embed", "vocab"), init="small")
    return d


def embed_tokens(cfg, p: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = x * jnp.asarray(cfg.embedding_multiplier, x.dtype)
    return lshard(x, "batch", "seq", "act_embed")


def logits_from_hidden(cfg, p: dict, x: jax.Array) -> jax.Array:
    dt = jnp.dtype(cfg.dtype)
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"].astype(dt))
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"].astype(dt))
    logits = logits / jnp.asarray(cfg.logits_scaling, logits.dtype)
    if cfg.attn_logit_softcap:  # (reused as final softcap when configured)
        c = cfg.attn_logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:  # mask vocab-padding slots
        pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_mask, jnp.asarray(-1e9, logits.dtype), logits)
    axes = ("batch",) + ("seq",) * (logits.ndim - 2) + ("act_vocab",)
    return lshard(logits, *axes)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Vocab-sharding-friendly CE: the gold logit is extracted with a
    one-hot contraction (fuses into the reduction and keeps the vocab dim
    sharded) instead of take_along_axis (which would all-gather logits)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(V)[None, None, :])
    gold = jnp.sum(logits * onehot, axis=-1)
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
