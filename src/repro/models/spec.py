"""Parameter specs: one source of truth for shapes, init, and sharding axes.

Each model family builds a nested dict of ``P`` specs; ``init_params``
materializes arrays, ``axes_tree`` yields the logical-axes pytree used to
derive NamedShardings, and ``abstract_params`` yields ShapeDtypeStructs for
the dry-run (no allocation).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class P:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # normal | zeros | ones | embed | small
    dtype: Optional[str] = None  # default: cfg.param_dtype
    fan_in: Optional[int] = None  # override for scaled init

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, P)


def tree_map_specs(fn, specs):
    return jax.tree.map(fn, specs, is_leaf=_is_spec)


def init_params(specs, rng: jax.Array, default_dtype: str = "float32"):
    """Materialize parameter arrays from the spec tree."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    rngs = jax.random.split(rng, len(leaves))

    def make(spec: P, key):
        dt = jnp.dtype(spec.dtype or default_dtype)
        if spec.init == "zeros":
            return jnp.zeros(spec.shape, dt)
        if spec.init == "ones":
            return jnp.ones(spec.shape, dt)
        if spec.init == "small":
            return jax.random.normal(key, spec.shape, jnp.float32).astype(dt) * 0.02
        if spec.init == "embed":
            return jax.random.normal(key, spec.shape, jnp.float32).astype(dt) * 0.02
        if spec.init == "rglru_a":
            # A parameter: softplus^-1 of decay in [0.9, 0.999]
            u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
            a = -0.5 * jnp.log(u)  # c*softplus(L) ~= -log(u)
            return jnp.log(jnp.expm1(jnp.maximum(a / 8.0, 1e-6))).astype(dt)
        if spec.init == "mamba_alog":
            a = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
            return jnp.log(a).astype(dt)
        if spec.init == "mamba_dt":
            dt0 = jnp.exp(jax.random.uniform(key, spec.shape, jnp.float32)
                          * (np.log(0.1) - np.log(0.001)) + np.log(0.001))
            return (dt0 + jnp.log(-jnp.expm1(-dt0))).astype(dt)  # inv softplus
        # fan-in scaled normal
        fan = spec.fan_in if spec.fan_in else (spec.shape[0] if spec.shape else 1)
        scale = 1.0 / np.sqrt(max(fan, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dt)

    arrs = [make(s, k) for s, k in zip(leaves, rngs)]
    return jax.tree.unflatten(treedef, arrs)


def abstract_params(specs, default_dtype: str = "float32"):
    """ShapeDtypeStruct tree (dry-run stand-ins, no allocation)."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype or default_dtype)),
        specs)


def axes_tree(specs):
    """Pytree of logical-axes tuples, matching the params pytree."""
    return tree_map_specs(lambda s: s.axes, specs)


def param_bytes(specs, default_dtype: str = "float32") -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return int(sum(np.prod(s.shape) * jnp.dtype(s.dtype or default_dtype).itemsize
                   for s in leaves))


def stack_specs(spec: P, n: int, axis_name: str = "layers") -> P:
    """Add a leading scanned-layers dimension to a spec."""
    return P((n,) + spec.shape, (axis_name,) + spec.axes,
             init=spec.init, dtype=spec.dtype,
             fan_in=spec.fan_in or (spec.shape[0] if spec.shape else None))


def stack_tree(specs, n: int):
    return tree_map_specs(lambda s: stack_specs(s, n), specs)
