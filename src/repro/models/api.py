"""Unified model construction + batch/input specs for every assigned arch."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecModel
from repro.models.transformer import LM


def build_model(cfg: ModelConfig, attn_impl: str = "chunked"):
    if cfg.is_encoder_decoder:
        return EncDecModel(cfg, attn_impl=attn_impl)
    return LM(cfg, attn_impl=attn_impl)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, rng=None,
               batch_override: int = 0) -> Dict[str, Any]:
    """Concrete random batch (smoke tests / examples)."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    B = batch_override or shape.global_batch
    S = shape.seq_len
    if cfg.is_encoder_decoder:
        r1, r2 = jax.random.split(rng)
        se, sd = S - S // 2, S // 2
        return {
            "frames": jax.random.normal(r1, (B, se, cfg.d_model), jnp.float32)
            .astype(jnp.dtype(cfg.dtype)),
            "tokens": jax.random.randint(r2, (B, sd), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                batch_override: int = 0) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    if cfg.is_encoder_decoder:
        se, sd = S - S // 2, S // 2
        return {
            "frames": jax.ShapeDtypeStruct((B, se, cfg.d_model),
                                           jnp.dtype(cfg.dtype)),
            "tokens": jax.ShapeDtypeStruct((B, sd), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def batch_axes(cfg: ModelConfig) -> Dict[str, Any]:
    """Logical axes for batch pytrees (tokens/frames sharded on batch)."""
    if cfg.is_encoder_decoder:
        return {"frames": ("batch", "seq", "act_embed"),
                "tokens": ("batch", "seq")}
    return {"tokens": ("batch", "seq")}
