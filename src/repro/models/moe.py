"""Mixture-of-Experts layer: top-k routing with two implementations.

``dense``  — reference: every expert computes every token, gated combine.
             O(E x) FLOPs; used by smoke tests and as the allclose oracle.
``ragged`` — production: sort token-copies by expert, grouped matmul via
             ``jax.lax.ragged_dot`` with a capacity bound. Runs single-device
             or expert-parallel (EP) under ``shard_map`` where each model-rank
             owns E/ep experts, computes only copies routed to them, and the
             combine is a psum over the EP axis. Expert weights are
             FSDP-sharded on d_model and all-gathered per layer (transient).

Both return ``(y, aux_loss)`` where aux is the switch-style load-balance
loss: E * sum_e(frac_tokens_e * mean_prob_e).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import axis_size
from repro.models.spec import P


def moe_specs(cfg) -> dict:
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    return {
        "router": P((d, m.num_experts), ("embed", None), init="small"),
        "wi": P((m.num_experts, d, f), ("experts", "expert_embed", "expert_mlp"),
                fan_in=d),
        "wg": P((m.num_experts, d, f), ("experts", "expert_embed", "expert_mlp"),
                fan_in=d),
        "wo": P((m.num_experts, f, d), ("experts", "expert_mlp", "expert_embed"),
                fan_in=f),
    }


def _act(cfg):
    return jax.nn.gelu if cfg.activation == "geglu" else jax.nn.silu


@jax.custom_vjp
def bf16_grad(x):
    """Identity with a bf16 cotangent: halves the FSDP reduce-scatter of
    expert-weight gradients (error well below optimizer noise; §Perf)."""
    return x


def _bf16_grad_fwd(x):
    return x, None


def _bf16_grad_bwd(_, g):
    return (g.astype(jnp.bfloat16).astype(g.dtype),)


bf16_grad.defvjp(_bf16_grad_fwd, _bf16_grad_bwd)

# §Perf knob: bf16 collectives for the MoE block (EP combine psum and
# FSDP grad reduce-scatter). Toggled by the dry-run hillclimb variants.
_BF16_COLLECTIVES = False


def set_moe_bf16_collectives(flag: bool) -> None:
    global _BF16_COLLECTIVES
    _BF16_COLLECTIVES = flag


def _route(cfg, router_w, x2d, dp_axis=None):
    """x2d: [T, D] -> (probs [T,E] f32, gate [T,k], idx [T,k], aux)."""
    m = cfg.moe
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, m.top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    # switch-style load-balance aux: E * sum_e f_e * p_e, where f_e is the
    # (stop-grad) fraction of routed assignments and p_e the mean router
    # prob. Under data-parallel shard_map both means are pmean'd over the
    # dp axis so the aux matches the global-batch value exactly.
    E = m.num_experts
    hard = jnp.zeros((x2d.shape[0], E), jnp.float32)
    hard = hard.at[jnp.arange(x2d.shape[0])[:, None], idx].set(1.0)
    frac = jax.lax.stop_gradient(hard.mean(0) / m.top_k)
    pbar = probs.mean(0)
    if dp_axis is not None:
        frac = jax.lax.pmean(frac, dp_axis)
        pbar = jax.lax.pmean(pbar, dp_axis)
    aux = E * jnp.sum(frac * pbar)
    return probs, gate, idx, aux


def moe_dense(cfg, p: dict, x: jax.Array):
    """Reference: [.., D] -> all-experts dense compute, gated combine."""
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    _, gate, idx, aux = _route(cfg, p["router"], x2)
    act = _act(cfg)
    h = jnp.einsum("td,edf->etf", x2, p["wi"].astype(dt))
    g = jnp.einsum("td,edf->etf", x2, p["wg"].astype(dt))
    h = act(g.astype(jnp.float32)).astype(dt) * h
    y_e = jnp.einsum("etf,efd->etd", h, p["wo"].astype(dt))  # [E,T,D]
    T = x2.shape[0]
    comb = jnp.zeros((T, m.num_experts), dt)
    comb = comb.at[jnp.arange(T)[:, None], idx].add(gate.astype(dt))
    y = jnp.einsum("etd,te->td", y_e, comb)
    return y.reshape(shape), aux


def _capacity(tokens_times_k: int, shards: int, cf: float) -> int:
    cap = int(math.ceil(tokens_times_k / shards * cf))
    return max(8, -(-cap // 8) * 8)  # round up to multiple of 8


def moe_ragged_local(cfg, p: dict, x: jax.Array, *,
                     ep_axis: Optional[str] = None,
                     fsdp_axis=None, dp_axis=None):
    """Sort + ragged_dot MoE. Call directly (single device) or inside
    shard_map with ``ep_axis`` = the expert-parallel mesh axis name."""
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    T = x2.shape[0]
    k = m.top_k

    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if fsdp_axis is not None:  # FSDP all-gather of expert weights (transient)
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axis, axis=2, tiled=True)
        if _BF16_COLLECTIVES:
            # bf16 cotangents -> the grad reduce-scatter (the transpose of
            # these gathers) moves half the bytes
            wi, wg, wo = bf16_grad(wi), bf16_grad(wg), bf16_grad(wo)

    _, gate, idx, aux = _route(cfg, p["router"], x2, dp_axis=dp_axis)

    E_local = wi.shape[0]
    ep = 1
    if ep_axis is not None:
        ep = axis_size(ep_axis)
        rank = jax.lax.axis_index(ep_axis)
        local_id = idx - rank * E_local
    else:
        local_id = idx
    own = (local_id >= 0) & (local_id < E_local)

    flat_id = jnp.where(own, local_id, E_local).reshape(-1)        # [T*k]
    flat_gate = jnp.where(own, gate, 0.0).reshape(-1)
    order = jnp.argsort(flat_id)                                    # stable
    cap = _capacity(T * k, ep, m.capacity_factor)
    cap = min(cap, T * k)
    sel = order[:cap]                                               # kept copies
    tok = sel // k                                                  # token of copy
    xs = x2[tok]                                                    # [cap, D]

    counts = jnp.bincount(flat_id, length=E_local + 1)[:E_local]
    cum = jnp.cumsum(counts)
    cum_cl = jnp.minimum(cum, cap)
    gs = jnp.concatenate([cum_cl[:1], jnp.diff(cum_cl)]).astype(jnp.int32)

    act = _act(cfg)
    h = jax.lax.ragged_dot(xs, wi.astype(dt), gs)
    g = jax.lax.ragged_dot(xs, wg.astype(dt), gs)
    h = act(g.astype(jnp.float32)).astype(dt) * h
    y_cp = jax.lax.ragged_dot(h, wo.astype(dt), gs)                 # [cap, D]

    w_cp = flat_gate[sel] * (jnp.arange(cap) < cum_cl[-1])          # drop overflow
    y = jnp.zeros((T, shape[-1]), jnp.float32)
    y = y.at[tok].add(y_cp.astype(jnp.float32) * w_cp[:, None])
    if ep_axis is not None:
        if _BF16_COLLECTIVES:  # EP combine in bf16: half the ICI bytes
            y = jax.lax.psum(y.astype(dt), ep_axis).astype(jnp.float32)
        else:
            y = jax.lax.psum(y, ep_axis)
    return y.astype(dt).reshape(shape), aux


def moe_batched_local(cfg, p: dict, x: jax.Array, *,
                      ep_axis: Optional[str] = None,
                      fsdp_axis=None, dp_axis=None):
    """Fixed per-expert capacity MoE via gather + batched matmul.

    The production TPU path (§Perf iteration on kimi-k2): sorted token
    copies are scattered into a dense [E_local, cap_e, D] buffer and each
    expert runs one MXU-friendly batched dot — no ragged/grouped kernel
    needed, and (unlike ragged_dot's CPU decomposition) no [E, T, D]
    expansion anywhere. Tokens beyond a per-expert capacity drop (classic
    Switch semantics, capacity_factor-controlled).
    """
    m = cfg.moe
    dt = jnp.dtype(cfg.dtype)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    T, D = x2.shape
    k = m.top_k

    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if fsdp_axis is not None:
        wi = jax.lax.all_gather(wi, fsdp_axis, axis=1, tiled=True)
        wg = jax.lax.all_gather(wg, fsdp_axis, axis=1, tiled=True)
        wo = jax.lax.all_gather(wo, fsdp_axis, axis=2, tiled=True)
        if _BF16_COLLECTIVES:
            wi, wg, wo = bf16_grad(wi), bf16_grad(wg), bf16_grad(wo)

    _, gate, idx, aux = _route(cfg, p["router"], x2, dp_axis=dp_axis)

    E_local = wi.shape[0]
    ep = 1
    if ep_axis is not None:
        ep = axis_size(ep_axis)
        rank = jax.lax.axis_index(ep_axis)
        local_id = idx - rank * E_local
    else:
        local_id = idx
    own = (local_id >= 0) & (local_id < E_local)

    # slot-level gather: each of the E_local*cap_e expert slots pulls its
    # token row directly (never materializing all T*k copies — 12.6x less
    # gather traffic at top-8 with 1.25x capacity; §Perf kimi iteration 2)
    cap_e = _capacity(T * k, ep * E_local, m.capacity_factor)
    flat_id = jnp.where(own, local_id, E_local).reshape(-1)       # [T*k]
    flat_gate = jnp.where(own, gate, 0.0).reshape(-1)
    order = jnp.argsort(flat_id)                                   # stable
    counts = jnp.bincount(flat_id, length=E_local + 1)[:E_local]
    starts = jnp.concatenate(
        [jnp.zeros(1, counts.dtype), jnp.cumsum(counts)])[:-1]
    n_slots = E_local * cap_e
    e_idx = jnp.arange(n_slots) // cap_e
    pos = jnp.arange(n_slots) % cap_e
    valid = pos < counts[e_idx]
    src = jnp.where(valid, starts[e_idx] + pos, 0)
    copy_idx = order[src]                                          # [slots]
    tok_slot = jnp.where(valid, copy_idx // k, T)                  # T = pad
    gate_slot = jnp.where(valid, flat_gate[copy_idx], 0.0)

    x2p = jnp.concatenate([x2.astype(dt), jnp.zeros((1, D), dt)], axis=0)
    xs = x2p[tok_slot].reshape(E_local, cap_e, D)

    act = _act(cfg)
    h = jnp.einsum("ecd,edf->ecf", xs, wi.astype(dt))
    g = jnp.einsum("ecd,edf->ecf", xs, wg.astype(dt))
    h = act(g.astype(jnp.float32)).astype(dt) * h
    y_e = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))             # [E,cap,D]

    y = jnp.zeros((T + 1, D), jnp.float32)
    y = y.at[tok_slot].add(
        y_e.reshape(-1, D).astype(jnp.float32)
        * gate_slot[:, None].astype(jnp.float32))[:T]
    if ep_axis is not None:
        if _BF16_COLLECTIVES:
            y = jax.lax.psum(y.astype(dt), ep_axis).astype(jnp.float32)
        else:
            y = jax.lax.psum(y, ep_axis)
    return y.astype(dt).reshape(shape), aux


_LOCAL_IMPLS = {"ragged": moe_ragged_local, "batched": moe_batched_local}


def moe_apply(cfg, p: dict, x: jax.Array, *, mesh=None, ep_axis: str = "model",
              fsdp_axes=None):
    """Dispatch on impl + mesh. x: [B, S, D] (replicated over 'model')."""
    local = _LOCAL_IMPLS.get(cfg.moe.impl, moe_ragged_local)
    if cfg.moe.impl == "dense" or mesh is None or ep_axis not in mesh.axis_names:
        if cfg.moe.impl == "dense":
            return moe_dense(cfg, p, x)
        return local(cfg, p, x)

    from jax.sharding import PartitionSpec as PS
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    fsdp = dp if fsdp_axes is None else fsdp_axes
    x_spec = PS(dp, None, None)
    p_specs = {
        "router": PS(None, None),
        "wi": PS(ep_axis, fsdp, None),
        "wg": PS(ep_axis, fsdp, None),
        "wo": PS(ep_axis, None, fsdp),
    }

    def inner(xl, pl):
        return local(cfg, pl, xl, ep_axis=ep_axis,
                     fsdp_axis=fsdp, dp_axis=dp)

    from repro.distributed.sharding import shard_map
    y, aux = shard_map(
        inner, mesh=mesh, in_specs=(x_spec, p_specs),
        out_specs=(x_spec, PS()))(x, p)
    return y, aux
