"""Mamba-2 (SSD, state-space duality) block [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm (matmul-friendly: quadratic
attention-like compute within chunks + a linear recurrence across chunk
states), which maps onto the MXU. Decode uses the O(1) recurrent update
``h = h*exp(dt*A) + dt * B ⊗ x``. Both paths share parameters.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models.spec import P


def _dims(cfg):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nheads = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.state_dim
    return s, d_in, nheads, conv_ch


def mamba_specs(cfg) -> dict:
    s, d_in, nheads, conv_ch = _dims(cfg)
    d = cfg.d_model
    return {
        "in_proj": P((d, 2 * d_in + 2 * s.n_groups * s.state_dim + nheads),
                     ("embed", None)),
        "conv_w": P((s.conv_dim, conv_ch), ("conv", None), init="small"),
        "conv_b": P((conv_ch,), (None,), init="zeros"),
        "a_log": P((nheads,), ("ssm_heads",), init="mamba_alog", dtype="float32"),
        "dt_bias": P((nheads,), ("ssm_heads",), init="mamba_dt", dtype="float32"),
        "d_skip": P((nheads,), ("ssm_heads",), init="ones", dtype="float32"),
        "norm_w": P((d_in,), ("act_rnn",), init="zeros"),
        "out_proj": P((d_in, d), ("rnn", "embed")),
    }


class MambaCache(NamedTuple):
    conv: jax.Array   # [L, B, conv_dim-1, conv_ch]
    state: jax.Array  # [L, B, H, P, N] f32


def init_mamba_cache(cfg, layers: int, batch: int) -> MambaCache:
    s, d_in, nheads, conv_ch = _dims(cfg)
    return MambaCache(
        jnp.zeros((layers, batch, s.conv_dim - 1, conv_ch), jnp.dtype(cfg.dtype)),
        jnp.zeros((layers, batch, nheads, s.head_dim, s.state_dim), jnp.float32))


def mamba_cache_axes() -> MambaCache:
    return MambaCache(("layers", "batch", None, "act_rnn"),
                      ("layers", "batch", "act_ssm_heads", None, None))


def _split_proj(cfg, zxbcdt):
    s, d_in, nheads, _ = _dims(cfg)
    gn = s.n_groups * s.state_dim
    z, x, B, C, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, x, B, C, dt


def _gated_norm(y, z, w, eps):
    dt = y.dtype
    y = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    return (y * jax.lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))).astype(dt)


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """Chunked SSD scan.

    x: [b,S,H,P]; dt: [b,S,H] (>0); A: [H] (<0); B,C: [b,S,G,N].
    Returns y: [b,S,H,P] and final state [b,H,P,N] (f32).
    """
    b, S, H, Pd = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    nc = S // chunk
    L = chunk
    f32 = jnp.float32

    xc = x.reshape(b, nc, L, H, Pd).astype(f32)
    dtc = dt.reshape(b, nc, L, H).astype(f32)
    Bc = jnp.repeat(B.reshape(b, nc, L, G, N), rep, axis=3).astype(f32)
    Cc = jnp.repeat(C.reshape(b, nc, L, G, N), rep, axis=3).astype(f32)

    lam = dtc * A[None, None, None, :]             # log-decay, <=0 [b,nc,L,H]
    cum = jnp.cumsum(lam, axis=2)                  # within-chunk cumulative
    total = cum[:, :, -1, :]                       # [b,nc,H]

    # ---- intra-chunk (quadratic within chunk, causal) --------------------
    # scores[i,j] = C_i·B_j * exp(cum_i - cum_j) * dt_j  for j <= i
    cb = jnp.einsum("bclhn,bcmhn->bchlm", Cc, Bc)  # [b,nc,H,L,L]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # [b,nc,l,m,H]
    decay = jnp.exp(jnp.moveaxis(diff, 4, 2))              # [b,nc,H,l,m]
    mask = jnp.tril(jnp.ones((L, L), bool))
    scores = jnp.where(mask[None, None, None], cb * decay, 0.0)
    xdt = xc * dtc[..., None]                      # [b,nc,L,H,P]
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", scores, xdt)

    # ---- chunk states + inter-chunk recurrence ---------------------------
    # state_c = sum_j B_j ⊗ xdt_j * exp(total - cum_j)
    dec_end = jnp.exp(total[:, :, None, :] - cum)  # [b,nc,L,H]
    st = jnp.einsum("bclhn,bclhp,bclh->bchpn", Bc, xc * dtc[..., None], dec_end)

    def step(h, xs):
        st_c, tot_c = xs
        h_new = h * jnp.exp(tot_c)[..., None, None] + st_c
        return h_new, h  # emit state *entering* this chunk

    h0 = jnp.zeros((b, H, Pd, N), f32)
    h_final, h_in = jax.lax.scan(
        step, h0, (jnp.moveaxis(st, 1, 0), jnp.moveaxis(total, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                # [b,nc,H,P,N]

    y_inter = jnp.einsum("bclhn,bchpn,bclh->bclhp", Cc, h_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, S, H, Pd)
    return y.astype(x.dtype), h_final


def mamba_apply(cfg, p: dict, x: jax.Array, *,
                return_state: bool = False):
    """Full-sequence mamba block. x: [B,S,D] -> [B,S,D]."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    dt_ = jnp.dtype(cfg.dtype)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    z, xin, B, C, dtr = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_out = jax.nn.silu(_causal_conv(conv_in, p["conv_w"].astype(dt_),
                                        p["conv_b"].astype(dt_)).astype(jnp.float32)).astype(dt_)
    xin, B, C = jnp.split(conv_out, [d_in, d_in + s.n_groups * s.state_dim], axis=-1)
    bsz, S = x.shape[0], x.shape[1]
    xh = xin.reshape(bsz, S, nheads, s.head_dim)
    xh = lshard(xh, "batch", "seq", "act_ssm_heads", None)
    Bg = B.reshape(bsz, S, s.n_groups, s.state_dim)
    Cg = C.reshape(bsz, S, s.n_groups, s.state_dim)
    dt_pos = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["a_log"])
    chunk = s.chunk if S % s.chunk == 0 and S >= s.chunk else S
    y, h_final = ssd_chunked(xh, dt_pos, A, Bg, Cg, chunk)
    y = y + xh.astype(y.dtype) * p["d_skip"][None, None, :, None].astype(y.dtype)
    y = y.reshape(bsz, S, d_in)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    out = lshard(out, "batch", "seq", "act_embed")
    if return_state:
        conv_state = conv_in[:, -(s.conv_dim - 1):, :]
        return out, (conv_state.astype(dt_), h_final)
    return out, None


def mamba_decode_step(cfg, p: dict, x: jax.Array, conv_state, state):
    """One-token step. x: [B,1,D]; conv_state: [B,K-1,C]; state: [B,H,P,N]."""
    s, d_in, nheads, conv_ch = _dims(cfg)
    dt_ = jnp.dtype(cfg.dtype)
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    z, xin, B, C, dtr = _split_proj(cfg, zxbcdt)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)  # [B,1,C]
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # [B,K,C]
    w = p["conv_w"].astype(dt_)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(dt_)
    conv_out = jax.nn.silu(conv_out.astype(jnp.float32)).astype(dt_)[:, None, :]
    xin, B, C = jnp.split(conv_out, [d_in, d_in + s.n_groups * s.state_dim], axis=-1)
    bsz = x.shape[0]
    xh = xin.reshape(bsz, nheads, s.head_dim).astype(jnp.float32)
    rep = nheads // s.n_groups
    Bg = jnp.repeat(B.reshape(bsz, s.n_groups, s.state_dim), rep, axis=1)
    Cg = jnp.repeat(C.reshape(bsz, s.n_groups, s.state_dim), rep, axis=1)
    dt_pos = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    A = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_pos * A[None, :])                     # [B,H]
    upd = jnp.einsum("bhn,bhp->bhpn", Bg, xh * dt_pos[..., None])
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", Cg, state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(dt_)
    y = _gated_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(dt_))
    return out, (window[:, 1:, :], state)
