from repro.models.api import batch_axes, build_model, input_specs, make_batch

__all__ = ["batch_axes", "build_model", "input_specs", "make_batch"]
