"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Recurrence: a_t = exp(-c * softplus(Λ) * r_t),
            h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)
with per-channel recurrence/input gates (r_t, i_t). Train/prefill uses
``jax.lax.associative_scan`` (log-depth on TPU); decode is the O(1) update.
The block wraps the recurrence with in/out projections, a short causal
conv, and a GeGLU-gated output branch, following the Griffin block layout.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models.spec import P

_C = 8.0  # Griffin's recurrence sharpness constant


def rglru_specs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    k = 4  # temporal conv width
    return {
        "in_x": P((d, w), ("embed", "rnn")),
        "in_gate": P((d, w), ("embed", "rnn")),
        "conv_w": P((k, w), ("conv", "rnn"), init="small"),
        "conv_b": P((w,), ("rnn",), init="zeros"),
        "a_param": P((w,), ("rnn",), init="rglru_a", dtype="float32"),
        "w_rgate": P((w,), ("rnn",), init="zeros", dtype="float32"),
        "b_rgate": P((w,), ("rnn",), init="zeros", dtype="float32"),
        "w_igate": P((w,), ("rnn",), init="zeros", dtype="float32"),
        "b_igate": P((w,), ("rnn",), init="zeros", dtype="float32"),
        "out": P((w, d), ("rnn", "embed")),
    }


class RGLRUCache(NamedTuple):
    conv: jax.Array  # [L, B, k-1, W]
    h: jax.Array     # [L, B, W] f32


def rglru_cache_axes() -> RGLRUCache:
    return RGLRUCache(("layers", "batch", None, "act_rnn"),
                      ("layers", "batch", "act_rnn"))


def _causal_conv(x, w, b):
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def _gates(p, xb):
    """Per-channel gates -> (log_a [B,S,W] (<=0), beta·i·x input term)."""
    xf = xb.astype(jnp.float32)
    r = jax.nn.sigmoid(xf * p["w_rgate"] + p["b_rgate"])
    i = jax.nn.sigmoid(xf * p["w_igate"] + p["b_igate"])
    log_a = -_C * jax.nn.softplus(p["a_param"]) * r          # <= 0
    a2 = jnp.exp(2.0 * log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9))
    return log_a, beta * i * xf


def rglru_apply(cfg, p: dict, x: jax.Array, *, return_state: bool = False):
    """Full-sequence Griffin recurrent block. x: [B,S,D]."""
    dt = jnp.dtype(cfg.dtype)
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(dt))
    gb = jnp.einsum("bsd,dw->bsw", x, p["in_gate"].astype(dt))
    xb = lshard(xb, "batch", "seq", "act_rnn")
    conv_in = xb
    xb = _causal_conv(xb, p["conv_w"].astype(dt), p["conv_b"].astype(dt))
    log_a, bix = _gates(p, xb)
    a = jnp.exp(log_a)

    def combine(lhs, rhs):
        a_l, b_l = lhs
        a_r, b_r = rhs
        return a_l * a_r, b_l * a_r + b_r

    a_sc, h = jax.lax.associative_scan(combine, (a, bix), axis=1)
    y = h * jax.nn.gelu(gb.astype(jnp.float32))
    out = jnp.einsum("bsw,wd->bsd", y.astype(dt), p["out"].astype(dt))
    out = lshard(out, "batch", "seq", "act_embed")
    if return_state:
        k = p["conv_w"].shape[0]
        return out, (conv_in[:, -(k - 1):, :].astype(dt), h[:, -1, :])
    return out, None


def rglru_decode_step(cfg, p: dict, x: jax.Array, conv_state, h):
    """One-token step. x: [B,1,D]; conv_state [B,k-1,W]; h [B,W] f32."""
    dt = jnp.dtype(cfg.dtype)
    xb = jnp.einsum("bsd,dw->bsw", x, p["in_x"].astype(dt))
    gb = jnp.einsum("bsd,dw->bsw", x, p["in_gate"].astype(dt))
    window = jnp.concatenate([conv_state, xb], axis=1)       # [B,k,W]
    w = p["conv_w"].astype(dt)
    xc = (jnp.einsum("bkw,kw->bw", window, w) + p["conv_b"].astype(dt))[:, None, :]
    log_a, bix = _gates(p, xc)
    h_new = jnp.exp(log_a[:, 0]) * h + bix[:, 0]
    y = h_new * jax.nn.gelu(gb[:, 0].astype(jnp.float32))
    out = jnp.einsum("bw,wd->bd", y.astype(dt), p["out"].astype(dt))[:, None, :]
    return out, (window[:, 1:, :], h_new)
