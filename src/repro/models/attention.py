"""Attention: GQA projections, chunked flash-style training attention,
and single-token decode attention over (full / windowed) KV caches.

The chunked path is the dry-run / XLA implementation: a static python loop
over Q chunks with a `lax.scan` over exactly the KV chunks each Q chunk can
see (causal triangle and/or sliding window), so HLO FLOPs match the true
work (no masked-away compute except the diagonal chunk). The Pallas kernel
in `repro.kernels.flash_attention` is the TPU-target version of the same
algorithm; `ref.py` oracles both.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import lshard
from repro.models.layers import apply_rope
from repro.models.spec import P

NEG_INF = -2.0 ** 30


def _largest_divisor(n: int, cap: int) -> int:
    for d in range(cap, 0, -1):
        if n % d == 0:
            return d
    return 1


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------

def attn_specs(cfg) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    s = {
        "wq": P((d, cfg.num_heads, hd), ("embed", "q_heads", "head_dim")),
        "wk": P((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d, cfg.num_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((cfg.num_heads, hd, d), ("q_heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = P((hd,), ("head_dim",), init="zeros")
        s["k_norm"] = P((hd,), ("head_dim",), init="zeros")
    return s


def _qk_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Core softmax-attention over chunks
# ---------------------------------------------------------------------------

def _scores(q, k, softcap):
    # q: [B, Sq, K, G, D]; k: [B, Sk, K, D] -> [B, K, G, Sq, Sk]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q.astype(jnp.float32),
                   k.astype(jnp.float32))
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    return s


def naive_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    q_offset: int = 0) -> jax.Array:
    """Reference attention; q: [B,Sq,Hq,D], k/v: [B,Sk,Hkv,D]."""
    B, Sq, Hq, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = Hq // K
    q = q.reshape(B, Sq, K, G, D) * (D ** -0.5)
    s = _scores(q, k, softcap)
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      softcap: Optional[float] = None,
                      q_chunk: int = 512, kv_chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention, FLOP-exact for causal/windowed.

    Static python loop over Q chunks; each runs a scan over exactly the KV
    chunks it can see. Memory per step: [B, K, G, q_chunk, kv_chunk].
    """
    B, S, Hq, D = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = Hq // K
    if S % q_chunk:  # adapt chunks to ragged lengths
        q_chunk = _largest_divisor(S, q_chunk)
    if Sk % kv_chunk:
        kv_chunk = _largest_divisor(Sk, kv_chunk)
    if causal and S != Sk:
        raise ValueError("causal chunked attention needs Sq == Sk")
    if S <= q_chunk or q_chunk < 64 or kv_chunk < 64:
        return naive_attention(q, k, v, causal=causal, window=window,
                               softcap=softcap)
    nq, nk = S // q_chunk, Sk // kv_chunk
    scale = D ** -0.5
    qc = q.reshape(B, nq, q_chunk, K, G, D)
    kc = k.reshape(B, nk, kv_chunk, K, D)
    vc = v.reshape(B, nk, kv_chunk, K, D)

    outs = []
    for i in range(nq):
        q_i = qc[:, i].astype(jnp.float32) * scale  # [B,Cq,K,G,D]
        q_lo, q_hi = i * q_chunk, (i + 1) * q_chunk - 1
        j_hi = (q_hi // kv_chunk) if causal else (nk - 1)
        j_lo = 0
        if window is not None:
            j_lo = max(0, (q_lo - window + 1) // kv_chunk)
        ks = kc[:, j_lo:j_hi + 1]
        vs = vc[:, j_lo:j_hi + 1]
        njs = j_hi - j_lo + 1

        def step(carry, xs):
            m, l, acc = carry
            kj, vj, j = xs
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, kj.astype(jnp.float32))
            if softcap:
                s = jnp.tanh(s / softcap) * softcap
            qpos = q_lo + jnp.arange(q_chunk)
            kpos = j * kv_chunk + jnp.arange(kv_chunk)
            msk = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                msk &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                msk &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p, vj.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, K, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, K, G, q_chunk, D), jnp.float32)
        js = jnp.arange(j_lo, j_hi + 1)
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), js))
        out_i = acc / jnp.maximum(l[..., None], 1e-30)      # [B,K,G,Cq,D]
        outs.append(jnp.moveaxis(out_i, 3, 1))               # [B,Cq,K,G,D]
    out = jnp.concatenate(outs, axis=1).reshape(B, S, Hq, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------

class KVCache(NamedTuple):
    """Full or windowed (circular) KV cache for one attention layer-stack.

    k/v: [L, B, W, Hkv, D]; index: scalar int32 — next absolute position.
    W == max_len for full caches, == window for circular caches.
    """
    k: jax.Array
    v: jax.Array
    index: jax.Array

    @property
    def window(self) -> int:
        return self.k.shape[2]


def init_kv_cache(cfg, layers: int, batch: int, max_len: int,
                  window: Optional[int] = None,
                  dtype=jnp.bfloat16) -> KVCache:
    W = min(window, max_len) if window else max_len
    shape = (layers, batch, W, cfg.num_kv_heads, cfg.resolved_head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                   jnp.zeros((), jnp.int32))


def cache_axes(_cfg) -> KVCache:
    ax = ("layers", "batch", "cache_seq", "act_kv_heads", "head_dim")
    return KVCache(ax, ax, ())


# §Perf baseline reproduction: the naive decode upcasts the whole cache to
# f32 (materializing an f32 copy per step). Toggled by the dry-run's
# 'baseline' variant only.
_DECODE_F32_UPCAST = False


def set_decode_f32_upcast(flag: bool) -> None:
    global _DECODE_F32_UPCAST
    _DECODE_F32_UPCAST = flag


def decode_attention(q, k_cache, v_cache, index, *,
                     window: Optional[int] = None,
                     softcap: Optional[float] = None) -> jax.Array:
    """One-token attention. q: [B,1,Hq,D]; caches: [B,W,Hkv,D].

    ``index`` is the absolute position of the new token; cache slot layout
    is circular when ``window`` is set (slot = pos % W), linear otherwise.
    """
    B, _, Hq, D = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    G = Hq // K
    if _DECODE_F32_UPCAST:  # baseline variant
        qf = q.reshape(B, K, G, D).astype(jnp.float32) * (D ** -0.5)
        s = jnp.einsum("bkgd,bskd->bkgs", qf,
                       k_cache.astype(jnp.float32))
    else:
        qf = (q.reshape(B, K, G, D) * (D ** -0.5)).astype(k_cache.dtype)
        # keep cache operands in their storage dtype; accumulate in f32 on
        # the MXU (preferred_element_type) — upcasting the cache would
        # materialize an f32 copy of the entire [L,B,S,K,D] buffer per step.
        s = jnp.einsum("bkgd,bskd->bkgs", qf, k_cache,
                       preferred_element_type=jnp.float32)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    slots = jnp.arange(W)
    if window is None:
        valid = slots <= index
    else:
        pos_of_slot = index - ((index - slots) % W)  # absolute pos in slot
        valid = (pos_of_slot >= 0) & (pos_of_slot > index - W) & (pos_of_slot <= index)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if _DECODE_F32_UPCAST:  # baseline variant
        out = jnp.einsum("bkgs,bskd->bkgd", p,
                         v_cache.astype(jnp.float32))
    else:
        out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                         preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention block (projections + rope + attention)
# ---------------------------------------------------------------------------

def attn_apply(cfg, p: dict, x: jax.Array, *, positions: jax.Array,
               causal: bool = True, window: Optional[int] = None,
               impl: str = "chunked",
               kv_for_cache: bool = False):
    """Multi-head GQA attention over a full sequence.

    Returns (out, (k, v)) — roped k and raw v for cache seeding when
    ``kv_for_cache``.
    """
    dt = jnp.dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:  # rope; None for non-positional (cross-attn)
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = lshard(q, "batch", "seq", "act_heads", None)
    k = lshard(k, "batch", "seq", "act_kv_heads", None)
    v = lshard(v, "batch", "seq", "act_kv_heads", None)
    if impl == "naive":
        o = naive_attention(q, k, v, causal=causal, window=window,
                            softcap=cfg.attn_logit_softcap)
    else:
        o = chunked_attention(q, k, v, causal=causal, window=window,
                              softcap=cfg.attn_logit_softcap)
    o = lshard(o, "batch", "seq", "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    out = lshard(out, "batch", "seq", "act_embed")
    if kv_for_cache:
        return out, (k, v)
    return out, None


def attn_decode_apply(cfg, p: dict, x: jax.Array, k_cache, v_cache,
                      index: jax.Array, *, window: Optional[int] = None):
    """One-token attention step. x: [B,1,D]; caches [B,W,Hkv,D].

    Returns (out, new_k_cache, new_v_cache).
    """
    dt = jnp.dtype(cfg.dtype)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = _qk_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_norm(k, p["k_norm"], cfg.norm_eps)
    pos = index[None] if index.ndim == 0 else index
    q = apply_rope(q, jnp.broadcast_to(pos, (x.shape[0], 1)), cfg.rope_theta)
    k = apply_rope(k, jnp.broadcast_to(pos, (x.shape[0], 1)), cfg.rope_theta)
    W = k_cache.shape[1]
    slot = index % W if window is not None else index
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
    o = decode_attention(q, k_cache, v_cache, index, window=window,
                         softcap=cfg.attn_logit_softcap)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))
    return out, k_cache, v_cache
