"""The paper's primary contribution: task-centric model selection
(NMF transferability subspace + online projection), the task registry,
and the mini zoo/transfer substrate used to validate it.
"""
from repro.core.features import TaskFeaturizer
from repro.core.forest import (DecisionTreeRegressor, RandomForestRegressor,
                               RidgeRegressor)
from repro.core.nmf import NMFResult, nmf, reconstruction_error
from repro.core.selection import (ModelSelector, SelectionReport,
                                  selection_regret)
from repro.core.task import TaskRegistry, TaskSpec
from repro.core.zoo import (FAMILIES, Task, ZooModel, build_tasks, build_zoo,
                            linear_probe_accuracy, make_task, pretrain_model,
                            transfer_matrix)

__all__ = [
    "TaskFeaturizer", "DecisionTreeRegressor", "RandomForestRegressor",
    "RidgeRegressor", "NMFResult", "nmf", "reconstruction_error",
    "ModelSelector", "SelectionReport", "selection_regret", "TaskRegistry",
    "TaskSpec", "FAMILIES", "Task", "ZooModel", "build_tasks", "build_zoo",
    "linear_probe_accuracy", "make_task", "pretrain_model", "transfer_matrix",
]
