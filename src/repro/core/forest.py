"""Random-forest regressor, from scratch (paper §4.3 uses a random forest
to map LVM forward features -> latent task embeddings).

CART regression trees with variance-reduction splits, feature and sample
bagging, multi-output leaves. Pure numpy — training sets here are small
(hundreds of historical tasks), so an exact quantile-threshold search is
affordable and dependency-free.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class _Node:
    feature: int = -1
    threshold: float = 0.0
    left: int = -1
    right: int = -1
    value: Optional[np.ndarray] = None  # leaf payload [out_dim]


class DecisionTreeRegressor:
    def __init__(self, max_depth: int = 8, min_samples_leaf: int = 2,
                 max_features: Optional[int] = None, rng: Optional[np.random.Generator] = None):
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.rng = rng or np.random.default_rng(0)
        self.nodes: List[_Node] = []

    def fit(self, X: np.ndarray, Y: np.ndarray) -> "DecisionTreeRegressor":
        self.nodes = []
        self._build(X, Y, depth=0)
        return self

    def _build(self, X, Y, depth) -> int:
        idx = len(self.nodes)
        self.nodes.append(_Node())
        n, d = X.shape
        if (depth >= self.max_depth or n < 2 * self.min_samples_leaf
                or np.allclose(Y.var(axis=0).sum(), 0.0)):
            self.nodes[idx].value = Y.mean(axis=0)
            return idx
        k = self.max_features or max(1, int(np.sqrt(d)))
        feats = self.rng.choice(d, size=min(k, d), replace=False)
        best = (None, None, np.inf)
        base_sse = ((Y - Y.mean(0)) ** 2).sum()
        for f in feats:
            xs = X[:, f]
            qs = np.unique(np.quantile(xs, np.linspace(0.1, 0.9, 9)))
            for t in qs:
                m = xs <= t
                nl = int(m.sum())
                if nl < self.min_samples_leaf or n - nl < self.min_samples_leaf:
                    continue
                yl, yr = Y[m], Y[~m]
                sse = (((yl - yl.mean(0)) ** 2).sum()
                       + ((yr - yr.mean(0)) ** 2).sum())
                if sse < best[2]:
                    best = (f, t, sse)
        if best[0] is None or best[2] >= base_sse:
            self.nodes[idx].value = Y.mean(axis=0)
            return idx
        f, t, _ = best
        m = X[:, f] <= t
        self.nodes[idx].feature = int(f)
        self.nodes[idx].threshold = float(t)
        self.nodes[idx].left = self._build(X[m], Y[m], depth + 1)
        self.nodes[idx].right = self._build(X[~m], Y[~m], depth + 1)
        return idx

    def predict(self, X: np.ndarray) -> np.ndarray:
        out = []
        for x in X:
            i = 0
            while self.nodes[i].value is None:
                nd = self.nodes[i]
                i = nd.left if x[nd.feature] <= nd.threshold else nd.right
            out.append(self.nodes[i].value)
        return np.stack(out)


class RandomForestRegressor:
    """Bagged multi-output CART forest (paper's regressor R, Eq. 3)."""

    def __init__(self, n_trees: int = 32, max_depth: int = 8,
                 min_samples_leaf: int = 2,
                 max_features: Optional[int] = None, seed: int = 0):
        self.n_trees = n_trees
        self.max_depth = max_depth
        self.min_samples_leaf = min_samples_leaf
        self.max_features = max_features
        self.seed = seed
        self.trees: List[DecisionTreeRegressor] = []

    def fit(self, X: np.ndarray, Y: np.ndarray) -> "RandomForestRegressor":
        X = np.asarray(X, np.float32)
        Y = np.asarray(Y, np.float32)
        if Y.ndim == 1:
            Y = Y[:, None]
        rng = np.random.default_rng(self.seed)
        n = X.shape[0]
        self.trees = []
        for t in range(self.n_trees):
            bag = rng.integers(0, n, size=n)
            tree = DecisionTreeRegressor(
                self.max_depth, self.min_samples_leaf, self.max_features,
                rng=np.random.default_rng(rng.integers(1 << 31)))
            tree.fit(X[bag], Y[bag])
            self.trees.append(tree)
        return self

    def predict(self, X: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        preds = np.stack([t.predict(X) for t in self.trees])
        return preds.mean(axis=0)


class RidgeRegressor:
    """Closed-form ridge alternative (JAX-friendly ablation baseline)."""

    def __init__(self, l2: float = 1e-2):
        self.l2 = l2
        self.Wb: Optional[np.ndarray] = None

    def fit(self, X, Y):
        X = np.asarray(X, np.float64)
        Y = np.asarray(Y, np.float64)
        if Y.ndim == 1:
            Y = Y[:, None]
        Xb = np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)
        A = Xb.T @ Xb + self.l2 * np.eye(Xb.shape[1])
        self.Wb = np.linalg.solve(A, Xb.T @ Y)
        return self

    def predict(self, X):
        X = np.asarray(X, np.float64)
        Xb = np.concatenate([X, np.ones((X.shape[0], 1))], axis=1)
        return Xb @ self.Wb
