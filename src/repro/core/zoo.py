"""Mini model-zoo + task generator for the selection experiments.

A real transfer-learning microcosm that runs on CPU in seconds:
  - *tasks* are classification datasets drawn from parameterized families
    (rotated Gaussian mixtures, nonlinear ring/spiral maps, sparse
    features) — the analogue of the paper's series/NLP/image datasets;
  - *zoo models* are frozen feature extractors "pretrained" on a source
    task (their projection encodes the source's class geometry: top
    class-scatter eigendirections + noise);
  - *transfer performance* = held-out accuracy of a least-squares linear
    probe on the frozen features — the standard transferability measure.

Models transfer better to tasks resembling their source family, so the
transfer matrix V has genuine low-rank structure for the NMF to find.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

FAMILIES = ("gauss", "ring", "sparse", "stripe")


def adapt_input_width(X: np.ndarray, d: int) -> np.ndarray:
    """Slice wide inputs / zero-pad narrow ones to feature width ``d``.

    The single source of truth for input-width adaptation: every
    execution path (numpy ``ZooModel.features`` and the staged device
    backends) must use this so backends stay numerically interchangeable.
    """
    if X.shape[1] >= d:
        return X[:, :d]
    return np.pad(X, ((0, 0), (0, d - X.shape[1])))


@dataclass
class Task:
    name: str
    family: str
    X: np.ndarray
    y: np.ndarray
    X_test: np.ndarray
    y_test: np.ndarray
    params: Dict = field(default_factory=dict)


def make_task(rng: np.random.Generator, family: str, *, n: int = 240,
              dim: int = 16, classes: int = 3, noise: float = 0.4,
              name: str = "") -> Task:
    n_test = max(60, n // 3)
    total = n + n_test
    rot = np.linalg.qr(rng.standard_normal((dim, dim)))[0]
    y = rng.integers(0, classes, size=total)
    if family == "gauss":
        cents = rng.standard_normal((classes, dim)) * 2.0
        X = cents[y] + rng.standard_normal((total, dim)) * noise * 2
    elif family == "ring":
        r = 1.0 + y * 1.2 + rng.standard_normal(total) * noise
        theta = rng.uniform(0, 2 * np.pi, total)
        base = np.stack([r * np.cos(theta), r * np.sin(theta)], axis=1)
        pad = rng.standard_normal((total, dim - 2)) * noise
        X = np.concatenate([base, pad], axis=1)
    elif family == "sparse":
        X = rng.standard_normal((total, dim)) * noise
        for c in range(classes):
            mask = y == c
            X[mask, c % dim] += 2.5
            X[mask, (c * 2 + 1) % dim] -= 1.5
    else:  # stripe: class = quantized linear projection
        w = rng.standard_normal(dim)
        z = rng.standard_normal((total, dim))
        proj = z @ w
        edges = np.quantile(proj, np.linspace(0, 1, classes + 1)[1:-1])
        y = np.digitize(proj, edges)
        X = z + rng.standard_normal((total, dim)) * noise
    X = (X @ rot).astype(np.float32)
    return Task(name or f"{family}-{rng.integers(1e6)}", family,
                X[:n], y[:n], X[n:], y[n:],
                params={"dim": dim, "classes": classes, "noise": noise})


@dataclass
class ZooModel:
    """Frozen feature extractor with a family-typical inductive bias.

    mode 'linear' -> tanh(X W)          (gauss-style class-scatter dirs)
    mode 'radial' -> RBF to source centers (ring-style geometry)
    mode 'relu'   -> relu(X W)          (sparse-style axis features)
    mode 'proj1d' -> soft bins of 1-D projections (stripe-style)
    Inductive-bias match drives transfer — the zoo analogue of the paper's
    ResNet/YOLO/ALBERT variants suiting different data regimes.
    """
    name: str
    source_family: str
    W: np.ndarray
    mode: str = "linear"
    centers: Optional[np.ndarray] = None
    sigma: float = 1.0
    meta: Dict = field(default_factory=dict)

    def features(self, X: np.ndarray) -> np.ndarray:
        Xp = adapt_input_width(X, self.W.shape[0])
        if self.mode == "radial":
            d2 = ((Xp[:, None, :] - self.centers[None]) ** 2).sum(-1)
            return np.exp(-d2 / (2 * self.sigma ** 2))
        Z = Xp @ self.W
        if self.mode == "relu":
            return np.maximum(Z, 0.0)
        if self.mode == "proj1d":
            return np.tanh(np.concatenate([Z, Z ** 2 - 1.0], axis=1))
        return np.tanh(Z)


_FAMILY_MODE = {"gauss": "linear", "ring": "radial", "sparse": "relu",
                "stripe": "proj1d"}


def pretrain_model(task: Task, width: int = 32, noise: float = 0.3,
                   seed: int = 0, name: str = "",
                   mode: Optional[str] = None) -> ZooModel:
    """'Pretraining': encode the source task's class-scatter directions
    under the model's inductive bias; off-source directions are only
    weakly represented (narrow capacity -> genuine specialization)."""
    rng = np.random.default_rng(seed)
    X, y = task.X, task.y
    dim = X.shape[1]
    mode = mode or _FAMILY_MODE[task.family]
    classes = np.unique(y)
    cents = np.stack([X[y == c].mean(axis=0) for c in classes])
    if mode == "radial":
        # centers sampled from the source task (per class)
        per = max(2, width // max(len(classes), 1))
        cs = []
        for c in classes:
            pts = X[y == c]
            cs.append(pts[rng.choice(len(pts), size=min(per, len(pts)),
                                     replace=False)])
        centers = np.concatenate(cs)[:width]
        centers = centers + noise * rng.standard_normal(centers.shape)
        sigma = float(np.median(np.linalg.norm(X - X.mean(0), axis=1))) + 1e-3
        return ZooModel(name or f"zoo-{task.family}-{seed}", task.family,
                        np.eye(dim, dtype=np.float32), mode="radial",
                        centers=centers.astype(np.float32), sigma=sigma)
    scatter = (cents - cents.mean(0)).T @ (cents - cents.mean(0))
    scatter += 0.05 * np.cov(X.T)
    vals, vecs = np.linalg.eigh(scatter)
    top = vecs[:, ::-1][:, :min(width, dim)]
    fill = rng.standard_normal((dim, max(0, width - top.shape[1]))) \
        * (0.15 / np.sqrt(dim))                       # weak off-source dirs
    W = np.concatenate([top, fill], axis=1)
    W = W + noise * rng.standard_normal(W.shape) / np.sqrt(dim)
    return ZooModel(name or f"zoo-{task.family}-{seed}", task.family,
                    W.astype(np.float32), mode=mode)


def linear_probe_accuracy(model: ZooModel, task: Task,
                          l2: float = 1e-2) -> float:
    """Held-out accuracy of a least-squares probe on frozen features —
    the transfer score ground truth v_ij."""
    F = model.features(task.X)
    Ft = model.features(task.X_test)
    classes = np.unique(task.y)
    Y = (task.y[:, None] == classes[None, :]).astype(np.float32)
    Fb = np.concatenate([F, np.ones((F.shape[0], 1), np.float32)], axis=1)
    A = Fb.T @ Fb + l2 * np.eye(Fb.shape[1], dtype=np.float32)
    Wp = np.linalg.solve(A, Fb.T @ Y)
    Ftb = np.concatenate([Ft, np.ones((Ft.shape[0], 1), np.float32)], axis=1)
    pred = classes[np.argmax(Ftb @ Wp, axis=1)]
    return float((pred == task.y_test).mean())


def build_zoo(n_models: int = 24, seed: int = 0) -> List[ZooModel]:
    rng = np.random.default_rng(seed)
    zoo = []
    for i in range(n_models):
        fam = FAMILIES[i % len(FAMILIES)]
        src = make_task(rng, fam, noise=float(rng.uniform(0.2, 0.6)))
        # 1 in 4 models carries a mismatched inductive bias (zoo diversity)
        mode = None
        if rng.random() < 0.25:
            mode = _FAMILY_MODE[FAMILIES[int(rng.integers(len(FAMILIES)))]]
        width = int(rng.integers(8, 40))              # capacity spread
        zoo.append(pretrain_model(src, width=width,
                                  noise=float(rng.uniform(0.1, 0.5)),
                                  seed=int(rng.integers(1 << 31)),
                                  name=f"zoo{i:02d}-{fam}", mode=mode))
    return zoo


def build_tasks(n_tasks: int = 40, seed: int = 1) -> List[Task]:
    rng = np.random.default_rng(seed)
    return [make_task(rng, FAMILIES[i % len(FAMILIES)],
                      dim=16, classes=int(rng.integers(2, 5)),
                      noise=float(rng.uniform(0.2, 0.7)),
                      name=f"task{i:03d}")
            for i in range(n_tasks)]


def transfer_matrix(zoo: List[ZooModel],
                    tasks: List[Task]) -> np.ndarray:
    """V[i, j] = probe accuracy of model i on task j (paper's historical
    transfer matrix)."""
    V = np.zeros((len(zoo), len(tasks)), np.float32)
    for i, m in enumerate(zoo):
        for j, t in enumerate(tasks):
            V[i, j] = linear_probe_accuracy(m, t)
    return V
