"""Task-centric interface (paper §2.1, Table 1): CREATE TASK / PREDICT.

``TaskRegistry`` is the declarative layer: users register high-level tasks
(input type, output labels, kind) and the system resolves each task to a
model via the two-phase selector + catalog, caching resolutions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import numpy as np


@dataclass(frozen=True)
class TaskSpec:
    name: str                       # e.g. "sentiment_classifier"
    input_type: str                 # text | image | series
    output_labels: tuple            # e.g. ("POS", "NEG", "NEU")
    kind: str = "classification"    # classification | regression
    constraints: Dict[str, Any] = field(default_factory=dict, hash=False)


class TaskRegistry:
    """CREATE TASK / REGISTER TASK / PREDICT <task> resolution."""

    def __init__(self, selector=None, zoo: Optional[list] = None):
        self.selector = selector
        self.zoo = zoo or []
        self._tasks: Dict[str, TaskSpec] = {}
        self._resolution: Dict[str, int] = {}       # task -> zoo index

    def create_task(self, spec: TaskSpec) -> None:
        if spec.name in self._tasks:
            raise ValueError(f"task {spec.name} already exists")
        self._tasks[spec.name] = spec

    def drop_task(self, name: str) -> None:
        self._tasks.pop(name, None)
        self._resolution.pop(name, None)

    def get(self, name: str) -> TaskSpec:
        return self._tasks[name]

    def list_tasks(self) -> List[TaskSpec]:
        return list(self._tasks.values())

    def resolve(self, name: str, X: np.ndarray, y: np.ndarray,
                force: bool = False) -> int:
        """Select the model for a task from sample data (cached)."""
        if name not in self._tasks:
            raise KeyError(f"unknown task {name}; CREATE TASK first")
        if not force and name in self._resolution:
            return self._resolution[name]
        if self.selector is None:
            raise RuntimeError("no selector attached")
        rep = self.selector.select(X, y)
        self._resolution[name] = rep.chosen
        return rep.chosen

    def predict_fn(self, name: str) -> Callable:
        """Returns the resolved model's inference callable for the DAG."""
        idx = self._resolution.get(name)
        if idx is None:
            raise RuntimeError(f"task {name} not resolved yet")
        model = self.zoo[idx]

        def fn(X: np.ndarray) -> np.ndarray:
            return model.features(np.asarray(X, np.float32))

        return fn
