"""Non-negative matrix factorization (paper §4.2, Eq. 2), in JAX.

Decomposes the historical transfer-performance matrix V [M models x N
tasks] into W [M x k] (model embeddings) and H [N x k] (task embeddings)
with multiplicative updates minimizing ||V - W H^T||_F^2 s.t. W,H >= 0.

Supports masked factorization (missing entries in V — not every model was
evaluated on every historical task) by weighting the objective.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-9


class NMFResult(NamedTuple):
    W: jax.Array          # [M, k] model embeddings
    H: jax.Array          # [N, k] task embeddings
    loss_curve: jax.Array


@functools.partial(jax.jit, static_argnames=("k", "iters"))
def nmf(V: jax.Array, k: int, *, iters: int = 300,
        mask: Optional[jax.Array] = None,
        seed: int = 0) -> NMFResult:
    M, N = V.shape
    rng = jax.random.PRNGKey(seed)
    r1, r2 = jax.random.split(rng)
    scale = jnp.sqrt(jnp.maximum(V.mean(), _EPS) / k)
    W = jax.random.uniform(r1, (M, k), jnp.float32, 0.1, 1.0) * scale
    H = jax.random.uniform(r2, (N, k), jnp.float32, 0.1, 1.0) * scale
    Vm = V if mask is None else V * mask

    def step(carry, _):
        W, H = carry
        WH = W @ H.T
        WHm = WH if mask is None else WH * mask
        # H <- H * (V^T W) / (WH^T W)
        H_new = H * (Vm.T @ W) / (WHm.T @ W + _EPS)
        WH = W @ H_new.T
        WHm = WH if mask is None else WH * mask
        W_new = W * (Vm @ H_new) / (WHm @ H_new + _EPS)
        resid = Vm - (W_new @ H_new.T if mask is None
                      else (W_new @ H_new.T) * mask)
        loss = jnp.sum(resid * resid)
        return (W_new, H_new), loss

    (W, H), losses = jax.lax.scan(step, (W, H), None, length=iters)
    return NMFResult(W, H, losses)


def reconstruction_error(V, W, H, mask=None) -> float:
    R = V - W @ H.T
    if mask is not None:
        R = R * mask
        denom = jnp.maximum(jnp.sum(mask * V * V), _EPS)
    else:
        denom = jnp.maximum(jnp.sum(V * V), _EPS)
    return float(jnp.sum(R * R) / denom)
