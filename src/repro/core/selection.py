"""Two-phase task-centric model selection (paper §4).

Offline: NMF of the transfer matrix V [M x N] -> W (model embeddings),
H (task embeddings); train regressor R: task features -> H rows.
Online: t* = R(features(task)); Trans(m_i, t*) = <w_i, t*>; argmax.
Selection is O(M x k) vector math — no per-model fine-tuning (the paper's
cost argument vs AutoML).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.features import TaskFeaturizer
from repro.core.forest import RandomForestRegressor, RidgeRegressor
from repro.core.nmf import nmf, reconstruction_error


@dataclass
class SelectionReport:
    chosen: int
    scores: np.ndarray
    online_ms: float


def _kcenter_rows(V: np.ndarray, k: int, seed: int = 0) -> List[int]:
    """Greedy k-center over rows — maximally diverse model behaviors."""
    rng = np.random.default_rng(seed)
    first = int(np.argmax(V.var(axis=1)))
    chosen = [first]
    d = np.linalg.norm(V - V[first], axis=1)
    for _ in range(k - 1):
        nxt = int(np.argmax(d))
        chosen.append(nxt)
        d = np.minimum(d, np.linalg.norm(V - V[nxt], axis=1))
    return chosen


class ModelSelector:
    """Two-phase selector. ``n_anchors > 0`` adds *landmark features*: the
    probe accuracy of a few diverse anchor models on the target sample —
    still O(anchors) forward passes + least-squares, no fine-tuning (the
    same cost class as the paper's LVM feature extraction)."""

    def __init__(self, k: int = 8, regressor: str = "forest",
                 nmf_iters: int = 400, seed: int = 0, n_anchors: int = 4):
        self.k = k
        self.nmf_iters = nmf_iters
        self.seed = seed
        self.n_anchors = n_anchors
        self.featurizer = TaskFeaturizer()
        if regressor == "forest":
            self.reg = RandomForestRegressor(n_trees=48, max_depth=9,
                                             seed=seed)
        elif regressor == "ridge":
            self.reg = RidgeRegressor(l2=1e-1)
        else:
            raise ValueError(regressor)
        self.W: Optional[np.ndarray] = None
        self.H: Optional[np.ndarray] = None
        self.anchor_idx: List[int] = []
        self.anchor_models: List = []
        self.offline_seconds: float = 0.0
        self.recon_error: float = 0.0

    # -- offline phase ----------------------------------------------------
    def fit_offline(self, V: np.ndarray, task_features: np.ndarray,
                    mask: Optional[np.ndarray] = None,
                    zoo: Optional[List] = None) -> "ModelSelector":
        """V: [M, N] historical transfer matrix; task_features: [N, F].
        With ``zoo`` given, anchor landmark features are enabled."""
        t0 = time.time()
        V = np.asarray(V, np.float32)
        res = nmf(V, self.k, iters=self.nmf_iters,
                  mask=None if mask is None else np.asarray(mask, np.float32),
                  seed=self.seed)
        self.W = np.asarray(res.W)
        self.H = np.asarray(res.H)
        self.recon_error = reconstruction_error(
            V, res.W, res.H,
            None if mask is None else np.asarray(mask, np.float32))
        feats = np.asarray(task_features, np.float32)
        if zoo is not None and self.n_anchors > 0:
            self.anchor_idx = _kcenter_rows(V, min(self.n_anchors, len(zoo)),
                                            self.seed)
            self.anchor_models = [zoo[i] for i in self.anchor_idx]
            # historical anchor features come directly from V
            feats = np.concatenate([feats, V[self.anchor_idx].T], axis=1)
        self.reg.fit(feats, self.H)
        self.offline_seconds = time.time() - t0
        return self

    # -- online phase -------------------------------------------------------
    def _online_features(self, X: np.ndarray, y: np.ndarray) -> np.ndarray:
        feats = self.featurizer.features(X, y)
        if self.anchor_models:
            from repro.core.zoo import Task, linear_probe_accuracy
            n = X.shape[0]
            cut = max(2, int(n * 0.7))
            t = Task("online", "?", X[:cut], y[:cut], X[cut:], y[cut:])
            anchors = np.array(
                [linear_probe_accuracy(m, t) for m in self.anchor_models],
                np.float32)
            feats = np.concatenate([feats, anchors])
        return feats

    def embed_task(self, feats: np.ndarray) -> np.ndarray:
        t = self.reg.predict(feats[None] if feats.ndim == 1 else feats)
        return t[0] if feats.ndim == 1 else t

    def scores(self, feats: np.ndarray) -> np.ndarray:
        t = self.embed_task(feats)
        return self.W @ t

    def select(self, X: np.ndarray, y: np.ndarray) -> SelectionReport:
        t0 = time.time()
        feats = self._online_features(X, y)
        s = self.scores(feats)
        return SelectionReport(int(np.argmax(s)), s,
                               (time.time() - t0) * 1e3)

    def rank(self, X: np.ndarray, y: np.ndarray, top: int = 5) -> List[int]:
        return list(np.argsort(-self.select(X, y).scores)[:top])


# ---------------------------------------------------------------------------
# Evaluation helpers (selection regret vs oracle / exhaustive baselines)
# ---------------------------------------------------------------------------

def selection_regret(selector: ModelSelector, V_true_col: np.ndarray,
                     X: np.ndarray, y: np.ndarray) -> Dict[str, float]:
    """Regret of the selector's pick vs the oracle-best model, plus the
    rank of the chosen model (1 = best)."""
    rep = selector.select(X, y)
    best = float(V_true_col.max())
    got = float(V_true_col[rep.chosen])
    order = np.argsort(-V_true_col)
    rank = int(np.where(order == rep.chosen)[0][0]) + 1
    return {"regret": best - got, "chosen_acc": got, "oracle_acc": best,
            "rank": rank, "online_ms": rep.online_ms}
