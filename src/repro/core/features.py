"""Task feature extraction (paper §4.3's CLIP forward features).

No CLIP offline; the stand-in is a *frozen* random-projection encoder plus
dataset meta-features — the mechanism the paper relies on (fixed pretrained
features whose geometry correlates with transferability) rather than the
specific network. Tasks drawn from similar distributions land close in
feature space, which is the assumption Eq. 3 needs.
"""
from __future__ import annotations

import numpy as np

_MAX_DIM = 512


class TaskFeaturizer:
    """(X, y) -> fixed-length task feature vector."""

    def __init__(self, proj_dim: int = 24, seed: int = 7):
        self.proj_dim = proj_dim
        rng = np.random.default_rng(seed)
        self._proj = rng.standard_normal((_MAX_DIM, proj_dim)).astype(
            np.float32) / np.sqrt(_MAX_DIM)

    @property
    def dim(self) -> int:
        # proj mean + proj std + class-geometry stats + meta
        return 2 * self.proj_dim + 6

    def features(self, X: np.ndarray, y: np.ndarray) -> np.ndarray:
        X = np.asarray(X, np.float32)
        y = np.asarray(y)
        n, d = X.shape
        W = self._proj[:d] if d <= _MAX_DIM else self._proj
        Xp = np.tanh((X[:, :_MAX_DIM] @ W))                  # frozen encoder
        mu = Xp.mean(axis=0)
        sd = Xp.std(axis=0)
        classes = np.unique(y)
        C = len(classes)
        # class geometry in encoder space (transfer-relevant structure)
        cents = np.stack([Xp[y == c].mean(axis=0) for c in classes]) \
            if C > 1 else np.zeros((1, Xp.shape[1]), np.float32)
        between = float(np.linalg.norm(cents - cents.mean(0), axis=1).mean())
        within = float(np.mean([Xp[y == c].std(axis=0).mean()
                                for c in classes])) if C > 1 else float(sd.mean())
        counts = np.array([(y == c).mean() for c in classes])
        entropy = float(-(counts * np.log(counts + 1e-12)).sum())
        meta = np.array([
            np.log1p(n), np.log1p(d), float(C),
            entropy, between, between / (within + 1e-6),
        ], np.float32)
        return np.concatenate([mu, sd, meta]).astype(np.float32)
