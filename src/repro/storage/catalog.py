"""Catalog tables (paper Fig. 2): model_info_table + model_layer_info_table.

A light embedded 'system catalog' kept as JSON on disk — the structural
analogue of MorphingDB's PostgreSQL tables, recording model metadata,
storage format, base-model lineage (decoupled storage), and per-layer
tensor locations for partial loading.
"""
from __future__ import annotations

import json
import threading
import time
from dataclasses import asdict, dataclass, field
from pathlib import Path
from typing import Dict, List, Optional


@dataclass
class ModelInfo:
    model_id: str
    version: str = "1.0"
    task_types: List[str] = field(default_factory=list)
    modality: str = "text"               # text | image | series | multimodal
    storage: str = "decoupled"           # blob | decoupled | api
    path: str = ""                       # blob file / layer-table dir / URL
    base_model: Optional[str] = None     # decoupled: architecture lineage
    param_count: int = 0
    created_at: float = field(default_factory=time.time)
    extra: Dict = field(default_factory=dict)


@dataclass
class LayerInfo:
    model_id: str
    layer_name: str                      # flattened pytree key path
    layer_index: int
    dtype: str
    shape: List[int]
    nbytes: int
    file: str                            # Mvec file relative to table dir
    delta_of: Optional[str] = None       # fine-tune delta base layer
    enc: str = "dense"                   # payload encoding on disk
    bound: float = 0.0                   # declared max abs reconstruction err


class Catalog:
    """Thread-safe JSON-backed catalog."""

    def __init__(self, root: Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._lock = threading.Lock()
        self._models: Dict[str, ModelInfo] = {}
        self._layers: Dict[str, List[LayerInfo]] = {}
        self._load()

    # -- persistence -----------------------------------------------------
    @property
    def _models_file(self) -> Path:
        return self.root / "model_info_table.json"

    @property
    def _layers_file(self) -> Path:
        return self.root / "model_layer_info_table.json"

    def _load(self) -> None:
        if self._models_file.exists():
            raw = json.loads(self._models_file.read_text())
            self._models = {k: ModelInfo(**v) for k, v in raw.items()}
        if self._layers_file.exists():
            raw = json.loads(self._layers_file.read_text())
            self._layers = {k: [LayerInfo(**e) for e in v]
                            for k, v in raw.items()}

    def _flush(self) -> None:
        tmp = self._models_file.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {k: asdict(v) for k, v in self._models.items()}, indent=1))
        tmp.replace(self._models_file)
        tmp = self._layers_file.with_suffix(".tmp")
        tmp.write_text(json.dumps(
            {k: [asdict(e) for e in v] for k, v in self._layers.items()},
            indent=1))
        tmp.replace(self._layers_file)

    def reload(self) -> None:
        """Re-read the catalog tables from disk. Long-lived readers in
        other processes (dispatch-tier workers) call this before
        resolving a model that another process may have registered after
        this catalog was constructed."""
        with self._lock:
            self._models = {}
            self._layers = {}
            self._load()

    # -- API ----------------------------------------------------------------
    def register_model(self, info: ModelInfo) -> None:
        with self._lock:
            self._models[info.model_id] = info
            self._flush()

    def register_layers(self, model_id: str, layers: List[LayerInfo]) -> None:
        with self._lock:
            self._layers[model_id] = layers
            self._flush()

    def get_model(self, model_id: str) -> ModelInfo:
        return self._models[model_id]

    def get_layers(self, model_id: str) -> List[LayerInfo]:
        return self._layers.get(model_id, [])

    def list_models(self, task_type: Optional[str] = None,
                    modality: Optional[str] = None) -> List[ModelInfo]:
        out = list(self._models.values())
        if task_type:
            out = [m for m in out if task_type in m.task_types]
        if modality:
            out = [m for m in out if m.modality == modality]
        return out

    def drop_model(self, model_id: str) -> None:
        with self._lock:
            self._models.pop(model_id, None)
            self._layers.pop(model_id, None)
            self._flush()
