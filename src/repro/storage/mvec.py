"""Mvec tensor representation (paper §3.2).

A shape-aware binary tensor format: a *shape array* (dimension sizes) and a
*data array* (row-major flattened elements), extended here with an explicit
dtype tag so bf16/f32/int8 zoo tensors round-trip losslessly between the
store and JAX. Supports SQL-style slicing and partial (range) loads without
deserializing the whole tensor — the property the paper uses for
fine-grained in-DB access, which we use for per-shard checkpoint reads and
width-sliced trunk resolution.

The ``flags`` byte tags what the payload *means*: ``FLAG_DELTA`` marks a
fine-tune delta tensor (``variant - base``, same shape/dtype as the base
layer) that only makes sense composed onto its base layer. The tag makes
delta files self-describing on disk, so a reader can never mistake a delta
for full weights (``DecoupledStore`` validates it on every delta read).

Wire layout (little-endian):
  magic  u32 = 0x4D564543 ("MVEC")
  dtype  u8 code | flags u8 | reserved u16
  ndim   u32
  shape  u64[ndim]
  data   raw bytes, row-major
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import BinaryIO, Optional, Sequence, Tuple, Union

import numpy as np

MAGIC = 0x4D564543

# flags byte: payload semantics beyond shape/dtype
FLAG_DELTA = 0x01      # fine-tune delta (variant - base); compose before use

_DTYPES = ["float32", "float64", "float16", "bfloat16", "int8", "int16",
           "int32", "int64", "uint8", "uint32", "bool"]
_DTYPE_CODE = {d: i for i, d in enumerate(_DTYPES)}

# bfloat16 has no numpy dtype; store as uint16 payload with the bf16 tag.
_NP_FOR = {"bfloat16": np.uint16, "bool": np.bool_}


def _np_dtype(name: str):
    return np.dtype(_NP_FOR.get(name, name))


def dtype_name(arr) -> str:
    name = str(arr.dtype)
    return name


@dataclass(frozen=True)
class MvecHeader:
    dtype: str
    shape: Tuple[int, ...]
    flags: int = 0

    @property
    def is_delta(self) -> bool:
        return bool(self.flags & FLAG_DELTA)

    @property
    def itemsize(self) -> int:
        return _np_dtype(self.dtype).itemsize

    @property
    def nbytes(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n * self.itemsize

    @property
    def header_size(self) -> int:
        return 12 + 8 * len(self.shape)


def encode(arr, flags: int = 0) -> bytes:
    """JAX/numpy array -> Mvec bytes (row-major, shape+dtype preserved).
    ``flags`` tags payload semantics (e.g. ``FLAG_DELTA``)."""
    name = dtype_name(arr)
    if name not in _DTYPE_CODE:
        raise ValueError(f"unsupported dtype {name}")
    np_arr = np.asarray(arr)
    if name == "bfloat16":
        np_arr = np_arr.view(np.uint16)
    if np_arr.ndim:  # NB: ascontiguousarray promotes 0-d -> 1-d
        np_arr = np.ascontiguousarray(np_arr)
    head = struct.pack("<IBBH I", MAGIC, _DTYPE_CODE[name], flags & 0xFF, 0,
                       np_arr.ndim)
    head += struct.pack(f"<{np_arr.ndim}Q", *np_arr.shape)
    return head + np_arr.tobytes()


def decode_header(buf: Union[bytes, memoryview]) -> MvecHeader:
    magic, code, flags, _r, ndim = struct.unpack_from("<IBBH I", buf, 0)
    if magic != MAGIC:
        raise ValueError("not an Mvec buffer")
    shape = struct.unpack_from(f"<{ndim}Q", buf, 12)
    return MvecHeader(_DTYPES[code], tuple(int(s) for s in shape),
                      flags=int(flags))


def decode(buf: Union[bytes, memoryview]):
    """Mvec bytes -> numpy array (bf16 returned via ml_dtypes if available,
    else as a uint16 view tagged by the caller)."""
    h = decode_header(buf)
    raw = np.frombuffer(buf, dtype=_np_dtype(h.dtype), offset=h.header_size,
                        count=int(np.prod(h.shape)) if h.shape else 1)
    arr = raw.reshape(h.shape)
    if h.dtype == "bfloat16":
        try:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        except ImportError:  # pragma: no cover
            pass
    return arr


def decode_slice(buf: Union[bytes, memoryview], start: int, stop: int):
    """Partial load: rows [start, stop) along axis 0 without reading the
    rest (the paper's SQL-level slicing / partial loading)."""
    h = decode_header(buf)
    if not h.shape:
        raise ValueError("cannot slice a scalar")
    rows = h.shape[0]
    start = min(max(0, start), rows)
    stop = min(max(stop, start), rows)
    row_elems = 1
    for d in h.shape[1:]:
        row_elems *= d
    offset = h.header_size + start * row_elems * h.itemsize
    raw = np.frombuffer(buf, dtype=_np_dtype(h.dtype), offset=offset,
                        count=(stop - start) * row_elems)
    out = raw.reshape((stop - start,) + h.shape[1:])
    if h.dtype == "bfloat16":
        try:
            import ml_dtypes
            out = out.view(ml_dtypes.bfloat16)
        except ImportError:  # pragma: no cover
            pass
    return out


def read_header(f: BinaryIO) -> MvecHeader:
    pos = f.tell()
    head = f.read(12)
    magic, code, flags, _r, ndim = struct.unpack("<IBBH I", head)
    if magic != MAGIC:
        raise ValueError("not an Mvec file")
    shape = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
    f.seek(pos)
    return MvecHeader(_DTYPES[code], tuple(int(s) for s in shape),
                      flags=int(flags))


def read_slice(f: BinaryIO, start: int, stop: int):
    """File-level partial read (seek + read only the requested rows)."""
    h = read_header(f)
    pos = f.tell()
    rows = h.shape[0]
    start = min(max(0, start), rows)
    stop = min(max(stop, start), rows)
    row_bytes = h.itemsize
    for d in h.shape[1:]:
        row_bytes *= d
    f.seek(pos + h.header_size + start * row_bytes)
    raw = f.read((stop - start) * row_bytes)
    arr = np.frombuffer(raw, dtype=_np_dtype(h.dtype)).reshape(
        (stop - start,) + h.shape[1:])
    f.seek(pos)
    if h.dtype == "bfloat16":
        try:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        except ImportError:  # pragma: no cover
            pass
    return arr
