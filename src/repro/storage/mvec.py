"""Mvec tensor representation (paper §3.2).

A shape-aware binary tensor format: a *shape array* (dimension sizes) and a
*data array* (row-major flattened elements), extended here with an explicit
dtype tag so bf16/f32/int8 zoo tensors round-trip losslessly between the
store and JAX. Supports SQL-style slicing and partial (range) loads without
deserializing the whole tensor — the property the paper uses for
fine-grained in-DB access, which we use for per-shard checkpoint reads and
width-sliced trunk resolution.

The ``flags`` byte tags what the payload *means*: ``FLAG_DELTA`` marks a
fine-tune delta tensor (``variant - base``, same shape/dtype as the base
layer) that only makes sense composed onto its base layer. The tag makes
delta files self-describing on disk, so a reader can never mistake a delta
for full weights (``DecoupledStore`` validates it on every delta read).

Compressed payload encodings (the NeurStore-style delta compression the
store applies to fine-tune residuals) keep the *logical* dtype/shape in
the header and select an aux header + packed payload via flags:

``FLAG_SPARSE``
    CSR-style index+value encoding for deltas where most entries are
    (near-)zero. Aux: ``nnz u64 | bound f64``; payload: ``nnz`` sorted
    i64 flat indices then ``nnz`` values in the logical dtype. Exact
    when ``bound == 0`` (only exact zeros dropped).
``FLAG_QUANT``
    Symmetric int8/int16 quantization of a dense float residual. Aux:
    ``code u8 | pad 3B | scale f64 | zero_point f64 | bound f64``;
    payload: fixed-width integer codes. Dequant is
    ``(codes - zero_point) * scale`` in float64, cast to the logical
    dtype; ``bound`` declares the max abs reconstruction error
    (``scale/2`` for round-to-nearest). ``zero_point`` is always 0 here
    so exact-zero delta entries stay exactly zero through a round trip.
``FLAG_PAGED``
    The payload lives in a content-hashed page store; the file holds
    only a page table. Aux: ``page_bytes u32 | npages u32`` then
    ``npages`` 32-byte sha256 digests of consecutive chunks of the
    dense row-major payload. Decoding requires the page store, so
    ``decode`` refuses paged buffers (``DecoupledStore`` resolves them).

All encodings support row-range slicing without materializing the full
tensor: quant/paged payloads are fixed-stride (seek), sparse payloads
binary-search the index array and read only the covered value range.

Wire layout (little-endian):
  magic  u32 = 0x4D564543 ("MVEC")
  dtype  u8 code | flags u8 | reserved u16
  ndim   u32
  shape  u64[ndim]
  aux    encoding-specific header (FLAG_SPARSE/FLAG_QUANT/FLAG_PAGED only)
  data   raw bytes, row-major (packed per encoding)
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import BinaryIO, Optional, Sequence, Tuple, Union

import numpy as np

MAGIC = 0x4D564543

# flags byte: payload semantics beyond shape/dtype
FLAG_DELTA = 0x01      # fine-tune delta (variant - base); compose before use
FLAG_SPARSE = 0x02     # CSR-style index+value payload (sparse residual)
FLAG_QUANT = 0x04      # int8/int16 quantized codes + scale/zero-point
FLAG_PAGED = 0x08      # payload is a page table into a content-hashed store

ENCODING_FLAGS = FLAG_SPARSE | FLAG_QUANT | FLAG_PAGED

_SPARSE_AUX = struct.Struct("<Qd")       # nnz, bound
_QUANT_AUX = struct.Struct("<B3xddd")    # code dtype, scale, zero_point, bound
_PAGED_AUX = struct.Struct("<II")        # page_bytes, npages
_DIGEST_SIZE = 32                        # sha256

_DTYPES = ["float32", "float64", "float16", "bfloat16", "int8", "int16",
           "int32", "int64", "uint8", "uint32", "bool"]
_DTYPE_CODE = {d: i for i, d in enumerate(_DTYPES)}

# bfloat16 has no numpy dtype; store as uint16 payload with the bf16 tag.
_NP_FOR = {"bfloat16": np.uint16, "bool": np.bool_}


def _np_dtype(name: str):
    return np.dtype(_NP_FOR.get(name, name))


def dtype_name(arr) -> str:
    name = str(arr.dtype)
    return name


@dataclass(frozen=True)
class MvecHeader:
    dtype: str
    shape: Tuple[int, ...]
    flags: int = 0

    @property
    def is_delta(self) -> bool:
        return bool(self.flags & FLAG_DELTA)

    @property
    def is_sparse(self) -> bool:
        return bool(self.flags & FLAG_SPARSE)

    @property
    def is_quant(self) -> bool:
        return bool(self.flags & FLAG_QUANT)

    @property
    def is_paged(self) -> bool:
        return bool(self.flags & FLAG_PAGED)

    @property
    def encoding(self) -> str:
        if self.is_sparse:
            return "sparse"
        if self.is_quant:
            return "quant"
        if self.is_paged:
            return "paged"
        return "dense"

    @property
    def itemsize(self) -> int:
        return _np_dtype(self.dtype).itemsize

    @property
    def size(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    @property
    def nbytes(self) -> int:
        return self.size * self.itemsize

    @property
    def header_size(self) -> int:
        return 12 + 8 * len(self.shape)


@dataclass(frozen=True)
class AuxInfo:
    """Decoded aux header of a compressed payload (``decode_aux``).
    ``bound`` is the declared max abs reconstruction error (0 = exact);
    ``aux_size`` is the aux header's byte length after the shape array."""
    encoding: str = "dense"
    bound: float = 0.0
    scale: float = 0.0
    zero_point: float = 0.0
    code_dtype: str = ""
    nnz: int = 0
    page_bytes: int = 0
    digests: Tuple[bytes, ...] = ()
    aux_size: int = 0


def _pack_header(name: str, shape: Sequence[int], flags: int) -> bytes:
    head = struct.pack("<IBBH I", MAGIC, _DTYPE_CODE[name], flags & 0xFF, 0,
                       len(shape))
    head += struct.pack(f"<{len(shape)}Q", *shape)
    return head


def payload_array(arr) -> Tuple[np.ndarray, str]:
    """Contiguous storage view of an array (bf16 -> uint16) plus its
    logical dtype name — the raw row-major bytes every encoding packs."""
    name = dtype_name(arr)
    if name not in _DTYPE_CODE:
        raise ValueError(f"unsupported dtype {name}")
    np_arr = np.asarray(arr)
    if name == "bfloat16":
        np_arr = np_arr.view(np.uint16)
    if np_arr.ndim:  # NB: ascontiguousarray promotes 0-d -> 1-d
        np_arr = np.ascontiguousarray(np_arr)
    return np_arr, name


def encode(arr, flags: int = 0) -> bytes:
    """JAX/numpy array -> Mvec bytes (row-major, shape+dtype preserved).
    ``flags`` tags payload semantics (e.g. ``FLAG_DELTA``); compressed
    encodings have their own constructors (``encode_sparse`` /
    ``encode_quant`` / ``encode_paged``)."""
    if flags & ENCODING_FLAGS:
        raise ValueError("use encode_sparse/encode_quant/encode_paged "
                         "for compressed payloads")
    np_arr, name = payload_array(arr)
    return _pack_header(name, np_arr.shape, flags) + np_arr.tobytes()


def encode_sparse(arr, flags: int = 0, eps: float = 0.0) -> bytes:
    """CSR-style sparse encoding: entries with ``|x| <= eps`` are
    dropped (``eps=0`` drops only exact zeros — lossless up to the sign
    of zero). The declared error bound is ``eps``."""
    if flags & ENCODING_FLAGS:
        raise ValueError("encoding flag bits are set by the encoder")
    np_arr, name = payload_array(arr)
    flat = np_arr.reshape(-1)
    if name == "bfloat16":
        keep = flat != 0          # uint16 view: drop +0.0 words only
    elif eps and np_arr.dtype.kind == "f":
        keep = np.abs(flat) > eps
    else:
        keep = flat != 0
    idx = np.flatnonzero(keep).astype(np.int64)
    vals = flat[idx]
    bound = float(eps) if np_arr.dtype.kind == "f" else 0.0
    head = _pack_header(name, np_arr.shape, (flags | FLAG_SPARSE) & 0xFF)
    aux = _SPARSE_AUX.pack(len(idx), bound)
    return head + aux + idx.tobytes() + vals.tobytes()


def encode_quant(arr, code_dtype: str = "int8", flags: int = 0) -> bytes:
    """Symmetric integer quantization of a float tensor:
    ``scale = max|x| / qmax``, ``zero_point = 0`` (exact zeros survive),
    round-to-nearest codes, declared bound ``scale/2``. Values must be
    finite (callers keep non-finite residuals dense)."""
    if flags & ENCODING_FLAGS:
        raise ValueError("encoding flag bits are set by the encoder")
    if code_dtype not in ("int8", "int16"):
        raise ValueError(f"unsupported quant code dtype {code_dtype}")
    np_arr, name = payload_array(arr)
    if np_arr.dtype.kind != "f":
        raise ValueError("quantization only applies to float tensors")
    qmax = 127 if code_dtype == "int8" else 32767
    max_abs = float(np.max(np.abs(np_arr))) if np_arr.size else 0.0
    if not np.isfinite(max_abs):
        raise ValueError("cannot quantize non-finite values")
    scale = max_abs / qmax
    if scale > 0.0:
        codes = np.clip(np.rint(np_arr.astype(np.float64) / scale),
                        -qmax, qmax).astype(code_dtype)
        bound = scale / 2.0
    else:
        codes = np.zeros(np_arr.shape, dtype=code_dtype)
        bound = 0.0
    head = _pack_header(name, np_arr.shape, (flags | FLAG_QUANT) & 0xFF)
    aux = _QUANT_AUX.pack(_DTYPE_CODE[code_dtype], scale, 0.0, bound)
    return head + aux + codes.tobytes()


def encode_paged(dtype: str, shape: Sequence[int], page_bytes: int,
                 digests: Sequence[bytes], flags: int = 0) -> bytes:
    """Page-table file for a tensor whose dense payload lives in a
    content-hashed page store (``npages`` sha256 digests of consecutive
    ``page_bytes`` chunks; the last chunk may be short)."""
    if flags & ENCODING_FLAGS:
        raise ValueError("encoding flag bits are set by the encoder")
    if dtype not in _DTYPE_CODE:
        raise ValueError(f"unsupported dtype {dtype}")
    for dg in digests:
        if len(dg) != _DIGEST_SIZE:
            raise ValueError("page digests must be 32-byte sha256")
    head = _pack_header(dtype, tuple(shape), (flags | FLAG_PAGED) & 0xFF)
    aux = _PAGED_AUX.pack(int(page_bytes), len(digests))
    return head + aux + b"".join(digests)


def decode_header(buf: Union[bytes, memoryview]) -> MvecHeader:
    magic, code, flags, _r, ndim = struct.unpack_from("<IBBH I", buf, 0)
    if magic != MAGIC:
        raise ValueError("not an Mvec buffer")
    shape = struct.unpack_from(f"<{ndim}Q", buf, 12)
    return MvecHeader(_DTYPES[code], tuple(int(s) for s in shape),
                      flags=int(flags))


def decode_aux(buf: Union[bytes, memoryview]) -> AuxInfo:
    """Parse the encoding-specific aux header (``AuxInfo(encoding='dense')``
    for plain payloads). ``buf`` needs only header + aux bytes."""
    h = decode_header(buf)
    off = h.header_size
    if h.is_sparse:
        nnz, bound = _SPARSE_AUX.unpack_from(buf, off)
        return AuxInfo(encoding="sparse", bound=float(bound), nnz=int(nnz),
                       aux_size=_SPARSE_AUX.size)
    if h.is_quant:
        code, scale, zp, bound = _QUANT_AUX.unpack_from(buf, off)
        return AuxInfo(encoding="quant", bound=float(bound),
                       scale=float(scale), zero_point=float(zp),
                       code_dtype=_DTYPES[code], aux_size=_QUANT_AUX.size)
    if h.is_paged:
        page_bytes, npages = _PAGED_AUX.unpack_from(buf, off)
        base = off + _PAGED_AUX.size
        digests = tuple(
            bytes(buf[base + i * _DIGEST_SIZE:base + (i + 1) * _DIGEST_SIZE])
            for i in range(npages))
        return AuxInfo(encoding="paged", page_bytes=int(page_bytes),
                       digests=digests,
                       aux_size=_PAGED_AUX.size + npages * _DIGEST_SIZE)
    return AuxInfo()


def _finish(arr: np.ndarray, dtype: str):
    if dtype == "bfloat16":
        try:
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        except ImportError:  # pragma: no cover
            pass
    return arr


def _dequant(codes: np.ndarray, aux: AuxInfo, dtype: str) -> np.ndarray:
    out = (codes.astype(np.float64) - aux.zero_point) * aux.scale
    return out.astype(_np_dtype(dtype))


def _row_elems(h: MvecHeader) -> int:
    n = 1
    for d in h.shape[1:]:
        n *= d
    return n


def _clip_rows(h: MvecHeader, start: int, stop: int) -> Tuple[int, int]:
    if not h.shape:
        raise ValueError("cannot slice a scalar")
    rows = h.shape[0]
    start = min(max(0, start), rows)
    stop = min(max(stop, start), rows)
    return start, stop


def decode(buf: Union[bytes, memoryview]):
    """Mvec bytes -> numpy array (bf16 returned via ml_dtypes if available,
    else as a uint16 view tagged by the caller). Sparse and quantized
    payloads decode to the dense logical tensor; paged payloads need the
    page store and are rejected here."""
    h = decode_header(buf)
    off = h.header_size
    if h.is_paged:
        raise ValueError("paged Mvec payloads resolve through a page store")
    if h.is_sparse:
        aux = decode_aux(buf)
        base = off + aux.aux_size
        idx = np.frombuffer(buf, np.int64, aux.nnz, base)
        vals = np.frombuffer(buf, _np_dtype(h.dtype), aux.nnz,
                             base + 8 * aux.nnz)
        out = np.zeros(h.size, dtype=_np_dtype(h.dtype))
        out[idx] = vals
        return _finish(out.reshape(h.shape), h.dtype)
    if h.is_quant:
        aux = decode_aux(buf)
        codes = np.frombuffer(buf, _np_dtype(aux.code_dtype), h.size,
                              off + aux.aux_size)
        return _finish(_dequant(codes, aux, h.dtype).reshape(h.shape),
                       h.dtype)
    raw = np.frombuffer(buf, dtype=_np_dtype(h.dtype), offset=off,
                        count=h.size)
    return _finish(raw.reshape(h.shape), h.dtype)


def decode_slice(buf: Union[bytes, memoryview], start: int, stop: int):
    """Partial load: rows [start, stop) along axis 0 without materializing
    the rest (the paper's SQL-level slicing / partial loading). Works for
    sparse (index binary search) and quantized (fixed-stride) payloads."""
    h = decode_header(buf)
    start, stop = _clip_rows(h, start, stop)
    row_elems = _row_elems(h)
    lo, hi = start * row_elems, stop * row_elems
    if h.is_paged:
        raise ValueError("paged Mvec payloads resolve through a page store")
    if h.is_sparse:
        aux = decode_aux(buf)
        base = h.header_size + aux.aux_size
        idx = np.frombuffer(buf, np.int64, aux.nnz, base)
        i0, i1 = np.searchsorted(idx, (lo, hi))
        vals = np.frombuffer(buf, _np_dtype(h.dtype), int(i1 - i0),
                             base + 8 * aux.nnz + int(i0) * h.itemsize)
        out = np.zeros(hi - lo, dtype=_np_dtype(h.dtype))
        out[idx[i0:i1] - lo] = vals
        return _finish(out.reshape((stop - start,) + h.shape[1:]), h.dtype)
    if h.is_quant:
        aux = decode_aux(buf)
        code_item = _np_dtype(aux.code_dtype).itemsize
        codes = np.frombuffer(buf, _np_dtype(aux.code_dtype), hi - lo,
                              h.header_size + aux.aux_size + lo * code_item)
        return _finish(
            _dequant(codes, aux, h.dtype)
            .reshape((stop - start,) + h.shape[1:]), h.dtype)
    offset = h.header_size + lo * h.itemsize
    raw = np.frombuffer(buf, dtype=_np_dtype(h.dtype), offset=offset,
                        count=hi - lo)
    return _finish(raw.reshape((stop - start,) + h.shape[1:]), h.dtype)


def read_header(f: BinaryIO) -> MvecHeader:
    pos = f.tell()
    head = f.read(12)
    magic, code, flags, _r, ndim = struct.unpack("<IBBH I", head)
    if magic != MAGIC:
        raise ValueError("not an Mvec file")
    shape = struct.unpack(f"<{ndim}Q", f.read(8 * ndim))
    f.seek(pos)
    return MvecHeader(_DTYPES[code], tuple(int(s) for s in shape),
                      flags=int(flags))


def read_aux(f: BinaryIO) -> Tuple[MvecHeader, AuxInfo]:
    """Read header + aux from a file without touching the data region
    (file position restored)."""
    pos = f.tell()
    h = read_header(f)
    if not (h.flags & ENCODING_FLAGS):
        return h, AuxInfo()
    f.seek(pos + h.header_size)
    if h.is_sparse:
        raw = f.read(_SPARSE_AUX.size)
    elif h.is_quant:
        raw = f.read(_QUANT_AUX.size)
    else:
        raw = f.read(_PAGED_AUX.size)
        page_bytes, npages = _PAGED_AUX.unpack(raw)
        raw += f.read(npages * _DIGEST_SIZE)
    f.seek(pos)
    return h, decode_aux(
        _pack_header(h.dtype, h.shape, h.flags) + raw)


def read_slice_counted(f: BinaryIO, start: int, stop: int
                       ) -> Tuple[np.ndarray, int, AuxInfo]:
    """File-level partial read: seek + read only the bytes the requested
    rows need. Returns ``(rows_array, bytes_read, aux)`` so callers can
    account actual disk I/O — for a sparse payload that is the full index
    array (consulted to locate the row range) plus the covered values;
    for quantized payloads just the covered codes."""
    pos = f.tell()
    h, aux = read_aux(f)
    start, stop = _clip_rows(h, start, stop)
    row_elems = _row_elems(h)
    lo, hi = start * row_elems, stop * row_elems
    out_shape = (stop - start,) + h.shape[1:]
    data0 = pos + h.header_size + aux.aux_size
    if h.is_paged:
        raise ValueError("paged Mvec payloads resolve through a page store")
    if h.is_sparse:
        f.seek(data0)
        idx = np.frombuffer(f.read(8 * aux.nnz), np.int64)
        i0, i1 = (int(x) for x in np.searchsorted(idx, (lo, hi)))
        f.seek(data0 + 8 * aux.nnz + i0 * h.itemsize)
        raw = f.read((i1 - i0) * h.itemsize)
        vals = np.frombuffer(raw, _np_dtype(h.dtype))
        out = np.zeros(hi - lo, dtype=_np_dtype(h.dtype))
        out[idx[i0:i1] - lo] = vals
        f.seek(pos)
        return (_finish(out.reshape(out_shape), h.dtype),
                aux.aux_size + 8 * aux.nnz + len(raw), aux)
    if h.is_quant:
        code_item = _np_dtype(aux.code_dtype).itemsize
        f.seek(data0 + lo * code_item)
        raw = f.read((hi - lo) * code_item)
        codes = np.frombuffer(raw, _np_dtype(aux.code_dtype))
        f.seek(pos)
        return (_finish(_dequant(codes, aux, h.dtype).reshape(out_shape),
                        h.dtype),
                aux.aux_size + len(raw), aux)
    f.seek(data0 + lo * h.itemsize)
    raw = f.read((hi - lo) * h.itemsize)
    arr = np.frombuffer(raw, dtype=_np_dtype(h.dtype)).reshape(out_shape)
    f.seek(pos)
    return _finish(arr, h.dtype), len(raw), aux


def read_slice(f: BinaryIO, start: int, stop: int):
    """File-level partial read (seek + read only the requested rows)."""
    return read_slice_counted(f, start, stop)[0]
