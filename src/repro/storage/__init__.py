from repro.storage import mvec
from repro.storage.catalog import Catalog, LayerInfo, ModelInfo
from repro.storage.checkpoint import CheckpointManager
from repro.storage.stores import (ApiModelRegistry, BlobStore,
                                  DecoupledStore, StoreStats,
                                  flatten_params, unflatten_like)

__all__ = [
    "mvec", "Catalog", "LayerInfo", "ModelInfo", "CheckpointManager",
    "ApiModelRegistry", "BlobStore", "DecoupledStore", "StoreStats",
    "flatten_params", "unflatten_like",
]
