"""Distributed checkpointing on the decoupled Mvec layer store.

Design (maps MorphingDB's partial-load property onto pod-scale training):
  - every parameter/optimizer leaf is one Mvec layer file (axis-0 ranges
    readable without touching the rest);
  - per-step checkpoints live under ``<root>/step_<N>/`` with an atomic
    COMMIT marker written last — a crashed save is never restorable;
  - saves can run asynchronously (background thread) double-buffered, so
    the train loop only blocks on the previous save;
  - restore can *reshard elastically*: a checkpoint written as S shard
    files per layer restores onto S' != S hosts via Mvec range reads.
"""
from __future__ import annotations

import json
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.storage import mvec
from repro.storage.stores import flatten_params, unflatten_like


class CheckpointManager:
    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.root / f"step_{step:08d}"

    def save(self, step: int, state, *, num_shards: int = 1) -> Path:
        """Blocking save. ``state`` is any pytree (params, opt, rng...)."""
        d = self._step_dir(step)
        tmp = d.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = flatten_params(state)
        index = {}
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            safe = key.replace("/", "__")
            if num_shards > 1 and arr.ndim >= 1 and arr.shape[0] >= num_shards:
                rows = arr.shape[0]
                bounds = [rows * i // num_shards for i in range(num_shards + 1)]
                files = []
                for s in range(num_shards):
                    fn = f"{safe}.shard{s:03d}.mvec"
                    (tmp / fn).write_bytes(
                        mvec.encode(arr[bounds[s]:bounds[s + 1]]))
                    files.append(fn)
                index[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                              "shards": files, "bounds": bounds}
            else:
                fn = f"{safe}.mvec"
                (tmp / fn).write_bytes(mvec.encode(arr))
                index[key] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                              "shards": [fn], "bounds": [0, arr.shape[0] if arr.ndim else 0]}
        (tmp / "index.json").write_text(json.dumps(index))
        (tmp / "COMMIT").write_text(str(time.time()))
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)
        self._gc()
        return d

    def save_async(self, step: int, state, *, num_shards: int = 1) -> None:
        """Non-blocking save; blocks only if a previous save is running."""
        self.wait()
        # snapshot to host memory before returning control
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self.save(step, host_state, num_shards=num_shards)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if p.is_dir() and (p / "COMMIT").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: Optional[int] = None,
                shard: Optional[int] = None, num_hosts: int = 1):
        """Restore full state, or host ``shard`` of ``num_hosts`` (elastic:
        num_hosts need not match the shard count at save time)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError("no committed checkpoint")
        d = self._step_dir(step)
        index = json.loads((d / "index.json").read_text())
        flat: Dict[str, Any] = {}
        for key, meta in index.items():
            flat[key] = self._read_leaf(d, meta, shard, num_hosts)
        return unflatten_like(template, flat), step

    def _read_leaf(self, d: Path, meta: dict, shard: Optional[int],
                   num_hosts: int):
        shape = meta["shape"]
        files, bounds = meta["shards"], meta["bounds"]
        if shard is None or not shape or shape[0] < num_hosts:
            parts = [mvec.decode((d / f).read_bytes()) for f in files]
            out = np.concatenate(parts, axis=0) if len(parts) > 1 else parts[0]
            return out.reshape(shape) if not shape else out
        # elastic per-host range read across saved shard files
        rows = shape[0]
        lo = rows * shard // num_hosts
        hi = rows * (shard + 1) // num_hosts
        pieces = []
        for i, f in enumerate(files):
            s_lo, s_hi = bounds[i], bounds[i + 1]
            a, b = max(lo, s_lo), min(hi, s_hi)
            if a >= b:
                continue
            with open(d / f, "rb") as fh:
                pieces.append(mvec.read_slice(fh, a - s_lo, b - s_lo))
        return np.concatenate(pieces, axis=0)
