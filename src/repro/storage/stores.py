"""Model stores (paper §3.1): BLOB all-in-one, decoupled layer tables with
fine-tune deltas and partial loading, and API-based external endpoints.

The decoupled store is also the substrate for distributed checkpointing
(`repro.storage.checkpoint`): each layer is an independent Mvec file, so a
restore can read any subset (elastic resharding, partial update, variant
reuse) — the paper's partial-load property at pod scale.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import threading
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.storage import mvec
from repro.storage.catalog import Catalog, LayerInfo, ModelInfo


def flatten_params(params) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def unflatten_like(template, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"missing layer {key}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# BLOB store
# ---------------------------------------------------------------------------

class BlobStore:
    """All-in-one serialized model object (architecture + params)."""

    def __init__(self, root: Path, catalog: Optional[Catalog] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.catalog = catalog

    def save(self, model_id: str, arch_meta: dict, params,
             task_types: Optional[List[str]] = None,
             modality: str = "text") -> Path:
        flat = flatten_params(params)
        payload = {
            "arch": arch_meta,
            "layers": {k: mvec.encode(np.asarray(v)) for k, v in flat.items()},
        }
        path = self.root / f"{model_id}.blob"
        with open(path, "wb") as f:
            pickle.dump(payload, f, protocol=4)
        if self.catalog:
            self.catalog.register_model(ModelInfo(
                model_id=model_id, storage="blob", path=str(path),
                task_types=task_types or [], modality=modality,
                param_count=int(sum(np.asarray(v).size for v in flat.values()))))
        return path

    def load(self, model_id: str, template=None):
        path = self.root / f"{model_id}.blob"
        with open(path, "rb") as f:
            payload = pickle.load(f)
        flat = {k: mvec.decode(b) for k, b in payload["layers"].items()}
        if template is not None:
            return payload["arch"], unflatten_like(template, flat)
        return payload["arch"], flat


# ---------------------------------------------------------------------------
# Decoupled store
# ---------------------------------------------------------------------------

@dataclass
class StoreStats:
    """I/O accounting for partial loading: how many bytes actually came
    off disk vs were served from the in-memory layer cache. Partial-load
    wins are exactly ``loaded_bytes`` staying below the stored size."""
    loads: int = 0               # load() / load_layer_rows() calls
    partial_loads: int = 0       # calls that read a subset (filter/slice)
    loaded_bytes: int = 0        # bytes read from disk
    cache_hits: int = 0
    cache_hit_bytes: int = 0     # bytes served from the layer cache


class DecoupledStore:
    """Architecture/parameters separation with per-layer Mvec files.

    Supports: partial loading (subset of layers), fine-tune *deltas*
    (store only changed layers referencing a base model), and
    range reads within a layer (Mvec slicing) for per-shard restore.

    Every read is accounted in :class:`StoreStats`, and layer tensors are
    cached in memory keyed by their *resolved* file path — delta layers
    reference base-model files, so two models sharing a trunk share one
    cached tensor (the NeurStore-style cross-model reuse).
    """

    def __init__(self, root: Path, catalog: Optional[Catalog] = None,
                 cache_layers: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.catalog = catalog or Catalog(self.root / "_catalog")
        self.cache_layers = cache_layers
        self._layer_cache: Dict[Tuple[str, Optional[Tuple[int, int]]],
                                np.ndarray] = {}
        self._cache_lock = threading.Lock()
        self.stats = StoreStats()

    def _dir(self, model_id: str) -> Path:
        return self.root / model_id

    def save(self, model_id: str, arch_meta: dict, params,
             base_model: Optional[str] = None,
             task_types: Optional[List[str]] = None,
             modality: str = "text") -> Path:
        """Save params as layer tables. With ``base_model``, only layers
        that differ from the base are written (delta storage)."""
        d = self._dir(model_id)
        d.mkdir(parents=True, exist_ok=True)
        prefix = str(d) + os.sep   # separator: 'm1' must not evict 'm10'
        with self._cache_lock:   # rewritten layer files invalidate caches
            self._layer_cache = {k: v for k, v in self._layer_cache.items()
                                 if not k[0].startswith(prefix)}
        (d / "architecture.json").write_text(json.dumps(arch_meta, indent=1))
        flat = flatten_params(params)
        base_flat: Dict[str, Any] = {}
        if base_model:
            base_flat = {li.layer_name: li
                         for li in self.catalog.get_layers(base_model)}
        layers: List[LayerInfo] = []
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            delta_of = None
            if base_model and key in base_flat:
                base_arr = self._read_layer_file(base_model, base_flat[key])
                if (base_arr.shape == arr.shape
                        and base_arr.tobytes() == arr.tobytes()):
                    # unchanged: reference base layer, write nothing
                    bi = base_flat[key]
                    layers.append(LayerInfo(
                        model_id=model_id, layer_name=key, layer_index=i,
                        dtype=str(arr.dtype), shape=list(arr.shape),
                        nbytes=arr.nbytes,
                        file=f"@{base_model}/{bi.file}",
                        delta_of=base_model))
                    continue
            fname = f"layer_{i:05d}.mvec"
            (d / fname).write_bytes(mvec.encode(arr))
            layers.append(LayerInfo(
                model_id=model_id, layer_name=key, layer_index=i,
                dtype=str(arr.dtype), shape=list(arr.shape),
                nbytes=arr.nbytes, file=fname, delta_of=delta_of))
        self.catalog.register_layers(model_id, layers)
        self.catalog.register_model(ModelInfo(
            model_id=model_id, storage="decoupled", path=str(d),
            base_model=base_model, task_types=task_types or [],
            modality=modality,
            param_count=int(sum(np.asarray(v).size for v in flat.values()))))
        return d

    def _layer_path(self, model_id: str, li: LayerInfo) -> Path:
        file = li.file
        if file.startswith("@"):  # delta reference into the base model
            ref_model, ref_file = file[1:].split("/", 1)
            return self._dir(ref_model) / ref_file
        return self._dir(model_id) / file

    def _read_layer_file(self, model_id: str, li: LayerInfo,
                         rows: Optional[Tuple[int, int]] = None):
        path = self._layer_path(model_id, li)
        key = (str(path), rows)
        if self.cache_layers:
            with self._cache_lock:
                cached = self._layer_cache.get(key)
            if cached is not None:
                self.stats.cache_hits += 1
                self.stats.cache_hit_bytes += cached.nbytes
                return cached
        with open(path, "rb") as f:
            if rows is not None:
                arr = mvec.read_slice(f, rows[0], rows[1])
                self.stats.loaded_bytes += arr.nbytes
            else:
                buf = f.read()
                arr = mvec.decode(buf)
                self.stats.loaded_bytes += len(buf)
        if self.cache_layers:
            with self._cache_lock:
                self._layer_cache[key] = arr
        return arr

    def load(self, model_id: str, template=None,
             layer_filter: Optional[Callable[[str], bool]] = None):
        """Full or partial load. ``layer_filter(name)`` selects layers."""
        arch = json.loads((self._dir(model_id) / "architecture.json")
                          .read_text())
        self.stats.loads += 1
        if layer_filter is not None:
            self.stats.partial_loads += 1
        flat = {}
        for li in self.catalog.get_layers(model_id):
            if layer_filter and not layer_filter(li.layer_name):
                continue
            flat[li.layer_name] = self._read_layer_file(model_id, li)
        if template is not None and layer_filter is None:
            return arch, unflatten_like(template, flat)
        return arch, flat

    def load_layer_rows(self, model_id: str, layer_name: str,
                        start: int, stop: int):
        """Range read within one layer (per-shard restore / width-sliced
        trunk path): only the requested rows' bytes leave the disk."""
        for li in self.catalog.get_layers(model_id):
            if li.layer_name == layer_name:
                self.stats.loads += 1
                self.stats.partial_loads += 1
                return self._read_layer_file(model_id, li, rows=(start, stop))
        raise KeyError(layer_name)

    def trunk_fingerprint(self, model_id: str,
                          prefix: str = "trunk/") -> str:
        """Identity of a model's trunk: the *resolved* file paths of its
        trunk layers — the same key the layer-tensor cache uses, so two
        models whose fine-tune deltas reference one base trunk (or two
        tasks resolving to the same stored model) fingerprint equal and
        can share a serving embed lane. Paths are bound to their layer
        names: the same file set wired to different layers is a
        different trunk."""
        pairs = sorted(
            (li.layer_name, str(self._layer_path(model_id, li)))
            for li in self.catalog.get_layers(model_id)
            if li.layer_name.startswith(prefix))
        if not pairs:
            return model_id
        digest = hashlib.sha1(
            "|".join(f"{n}={p}" for n, p in pairs).encode()
        ).hexdigest()[:16]
        return f"trunk:{digest}"

    def stored_bytes(self, model_id: str) -> int:
        """Actual new bytes on disk (deltas count 0 for referenced layers)."""
        total = 0
        for li in self.catalog.get_layers(model_id):
            if not li.file.startswith("@"):
                total += (self._dir(model_id) / li.file).stat().st_size
        return total


# ---------------------------------------------------------------------------
# API-based models (simulated remote endpoints)
# ---------------------------------------------------------------------------

class ApiModelRegistry:
    """External model endpoints as logical operators (paper §3.1).

    No real network in this environment: endpoints are callables with a
    latency model, retry/timeout logic, and a response cache — the same
    control surface the paper describes for remote closed-source models.
    """

    def __init__(self, catalog: Optional[Catalog] = None):
        self.catalog = catalog
        self._endpoints: Dict[str, dict] = {}
        self._cache: Dict[Tuple[str, bytes], Any] = {}
        self.stats: Dict[str, Dict[str, float]] = {}

    def register(self, model_id: str, fn: Callable, *,
                 url: str = "https://api.example/v1",
                 latency_s: float = 0.05, jitter_s: float = 0.0,
                 failure_rate: float = 0.0, quota: Optional[int] = None,
                 timeout_s: float = 1.0, max_retries: int = 3,
                 cache: bool = True) -> None:
        self._endpoints[model_id] = dict(
            fn=fn, url=url, latency_s=latency_s, jitter_s=jitter_s,
            failure_rate=failure_rate, quota=quota, used=0,
            timeout_s=timeout_s, max_retries=max_retries, cache=cache)
        self.stats[model_id] = {"calls": 0, "retries": 0, "cache_hits": 0,
                                "latency_total": 0.0}
        if self.catalog:
            self.catalog.register_model(ModelInfo(
                model_id=model_id, storage="api", path=url,
                extra={"latency_s": latency_s}))

    def invoke(self, model_id: str, payload, rng: Optional[np.random.Generator] = None):
        ep = self._endpoints[model_id]
        st = self.stats[model_id]
        rng = rng or np.random.default_rng(0)
        key = None
        if ep["cache"]:
            try:
                key = (model_id, pickle.dumps(np.asarray(payload)))
            except Exception:
                key = None
            if key is not None and key in self._cache:
                st["cache_hits"] += 1
                return self._cache[key]
        if ep["quota"] is not None and ep["used"] >= ep["quota"]:
            raise RuntimeError(f"quota exhausted for {model_id}")
        last_err = None
        for attempt in range(ep["max_retries"] + 1):
            st["calls"] += 1
            ep["used"] += 1
            lat = ep["latency_s"] + float(rng.random()) * ep["jitter_s"]
            if lat > ep["timeout_s"]:
                st["retries"] += 1
                last_err = TimeoutError(f"{model_id} timed out")
                continue
            if ep["failure_rate"] and float(rng.random()) < ep["failure_rate"]:
                st["retries"] += 1
                last_err = ConnectionError(f"{model_id} transient failure")
                continue
            st["latency_total"] += lat
            time.sleep(min(lat, 0.002))  # token sleep, keep tests fast
            out = ep["fn"](payload)
            if key is not None:
                self._cache[key] = out
            return out
        raise last_err or RuntimeError("unreachable")

    def expected_latency(self, model_id: str) -> float:
        return self._endpoints[model_id]["latency_s"]
