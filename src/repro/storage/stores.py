"""Model stores (paper §3.1): BLOB all-in-one, decoupled layer tables with
fine-tune deltas and partial loading, and API-based external endpoints.

This module is the storage half of the cost model's TransCost term
(Eq. 7): ``ModelSize/MemBW + ModelSize/AccelBW`` is paid on the bytes a
resolution actually reads, so everything here is about shrinking
``ModelSize`` without changing the served model — partial loads read a
subset of layers (or a row range inside one, §3.2 Mvec slicing), and
fine-tune *deltas* store a variant as references to unchanged base
layers plus small per-layer delta tensors composed back at read time
(``base + delta``; the NeurStore-style delta compression argument).
``trunk_fingerprint`` turns the resolved layer identity into the lane
key the serving path (Eq. 11 row budgets, ``docs/serving.md``) uses to
coalesce fine-tunes of one base into a single embed lane. The remote
``ApiModelRegistry`` models Eq. 5's end-to-end latency term.
See ``docs/architecture.md`` for where each store sits in the dataflow.

The decoupled store is also the substrate for distributed checkpointing
(`repro.storage.checkpoint`): each layer is an independent Mvec file, so a
restore can read any subset (elastic resharding, partial update, variant
reuse) — the paper's partial-load property at pod scale.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import pickle
import shutil
import struct
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass, replace as dc_replace
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.storage import mvec
from repro.storage.catalog import Catalog, LayerInfo, ModelInfo


def flatten_params(params) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def unflatten_like(template, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(_path_str(p) for p in path)
        if key not in flat:
            raise KeyError(f"missing layer {key}")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# BLOB store
# ---------------------------------------------------------------------------

class BlobStore:
    """All-in-one serialized model object (architecture + params)."""

    def __init__(self, root: Path, catalog: Optional[Catalog] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.catalog = catalog

    def save(self, model_id: str, arch_meta: dict, params,
             task_types: Optional[List[str]] = None,
             modality: str = "text") -> Path:
        flat = flatten_params(params)
        payload = {
            "arch": arch_meta,
            "layers": {k: mvec.encode(np.asarray(v)) for k, v in flat.items()},
        }
        path = self.root / f"{model_id}.blob"
        with open(path, "wb") as f:
            pickle.dump(payload, f, protocol=4)
        if self.catalog:
            self.catalog.register_model(ModelInfo(
                model_id=model_id, storage="blob", path=str(path),
                task_types=task_types or [], modality=modality,
                param_count=int(sum(np.asarray(v).size for v in flat.values()))))
        return path

    def load(self, model_id: str, template=None):
        path = self.root / f"{model_id}.blob"
        with open(path, "rb") as f:
            payload = pickle.load(f)
        flat = {k: mvec.decode(b) for k, b in payload["layers"].items()}
        if template is not None:
            return payload["arch"], unflatten_like(template, flat)
        return payload["arch"], flat


# ---------------------------------------------------------------------------
# Decoupled store
# ---------------------------------------------------------------------------

@dataclass
class StoreStats:
    """I/O accounting for partial loading: how many bytes actually came
    off disk vs were served from the in-memory layer cache. Partial-load
    wins are exactly ``loaded_bytes`` staying below the stored size."""
    loads: int = 0               # load() / load_layer_rows() calls
    partial_loads: int = 0       # calls that read a subset (filter/slice)
    loaded_bytes: int = 0        # bytes read from disk
    cache_hits: int = 0
    cache_hit_bytes: int = 0     # bytes served from the layer cache
    cache_evictions: int = 0     # tensors LRU-evicted over the byte cap
    cache_evicted_bytes: int = 0
    cache_bytes: int = 0         # tensor bytes currently held (gauge)
    delta_composes: int = 0      # base+delta compositions performed
    delta_bytes: int = 0         # delta bytes (subset of loaded_bytes)
    dedup_pages: int = 0         # page writes elided (content already stored)
    dedup_bytes_saved: int = 0   # bytes those elided page writes would cost
    compressed_delta_bytes: int = 0  # on-disk bytes of compressed delta files
    quant_error_bound: float = 0.0   # max declared quant bound seen (gauge)


class PageStore:
    """Content-hashed, refcounted tensor pages (NeurStore-style dedup).

    Layer payloads are chunked into fixed-size pages keyed by the sha256
    of their content; identical trunk pages across zoo models and
    fine-tune chains are stored once. Refcounts persist in a JSON
    sidecar updated atomically; ``decref`` only drops the count (the
    page file stays on disk until :meth:`vacuum` collects orphans), so a
    crash between a decref and a vacuum can never lose referenced data —
    the failure mode is garbage, which the next vacuum removes.
    """

    REFS_FILE = "_refcounts.json"

    def __init__(self, root: Path, page_bytes: int = 64 << 10):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.page_bytes = int(page_bytes)
        self._lock = threading.Lock()
        self._refs: Dict[str, int] = {}
        refs_path = self.root / self.REFS_FILE
        if refs_path.exists():
            self._refs = {k: int(v) for k, v in
                          json.loads(refs_path.read_text()).items()}

    def _page_path(self, hex_digest: str) -> Path:
        return self.root / f"{hex_digest}.page"

    def _flush_locked(self) -> None:
        tmp = self.root / (self.REFS_FILE + ".tmp")
        tmp.write_text(json.dumps(self._refs, indent=0))
        tmp.replace(self.root / self.REFS_FILE)

    def chunk_digests(self, data: bytes) -> List[bytes]:
        return [hashlib.sha256(data[i:i + self.page_bytes]).digest()
                for i in range(0, len(data), self.page_bytes)] if data \
            else []

    def put(self, data: bytes) -> Tuple[List[bytes], int, int]:
        """Store a payload's pages and take one reference on each.
        Returns ``(digests, dup_pages, dup_bytes)`` — the dedup counters
        tell how many page writes were elided because the content was
        already stored (by this model or any other)."""
        digests: List[bytes] = []
        dup_pages = dup_bytes = 0
        with self._lock:
            for off in range(0, len(data), self.page_bytes):
                chunk = data[off:off + self.page_bytes]
                dg = hashlib.sha256(chunk).digest()
                digests.append(dg)
                hexd = dg.hex()
                path = self._page_path(hexd)
                if hexd in self._refs and path.exists():
                    dup_pages += 1
                    dup_bytes += len(chunk)
                else:
                    tmp = path.with_suffix(".tmp")
                    tmp.write_bytes(chunk)
                    tmp.replace(path)
                self._refs[hexd] = self._refs.get(hexd, 0) + 1
            self._flush_locked()
        return digests, dup_pages, dup_bytes

    def incref(self, digests) -> None:
        with self._lock:
            for dg in digests:
                self._refs[dg.hex()] = self._refs.get(dg.hex(), 0) + 1
            self._flush_locked()

    def decref(self, digests) -> None:
        with self._lock:
            for dg in digests:
                hexd = dg.hex()
                left = self._refs.get(hexd, 0) - 1
                if left > 0:
                    self._refs[hexd] = left
                else:
                    self._refs.pop(hexd, None)
            self._flush_locked()

    def refcount(self, digest: bytes) -> int:
        with self._lock:
            return self._refs.get(digest.hex(), 0)

    def read_page(self, digest: bytes) -> bytes:
        return self._page_path(digest.hex()).read_bytes()

    def page_size_on_disk(self, digest: bytes) -> int:
        path = self._page_path(digest.hex())
        return path.stat().st_size if path.exists() else 0

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.root.glob("*.page"))

    def vacuum(self) -> Tuple[int, int]:
        """GC orphaned pages: remove every ``*.page`` file whose digest
        holds no reference. Returns ``(pages_removed, bytes_freed)``.
        Referenced pages are never touched."""
        removed = freed = 0
        with self._lock:
            for path in list(self.root.glob("*.page")):
                if path.stem not in self._refs:
                    freed += path.stat().st_size
                    path.unlink()
                    removed += 1
            for path in self.root.glob("*.tmp"):   # crash leftovers
                path.unlink()
        return removed, freed


class DecoupledStore:
    """Architecture/parameters separation with per-layer Mvec files.

    Supports: partial loading (subset of layers), fine-tune *deltas*,
    and range reads within a layer (Mvec slicing) for per-shard restore.

    ``save(base_model=...)`` stores a fine-tuned variant at its marginal
    cost: layers identical to the base become references (zero new
    bytes), and changed same-geometry layers become per-layer *delta*
    tensors (``variant - base``, tagged ``mvec.FLAG_DELTA`` on disk).
    Reads compose ``base + delta`` transparently — integer deltas
    round-trip exactly (wraparound), float deltas within 1 ulp — and
    row-range reads slice base and delta consistently, so width-sliced
    partial loads work for deltas too.

    Every read is accounted in :class:`StoreStats`, and layer tensors are
    cached in memory keyed by their *resolved* file path — referenced
    layers resolve into the base model's files, so two models sharing a
    trunk share one cached tensor (the NeurStore-style cross-model
    reuse), and a fine-tune resolved after its base pays only delta
    bytes of disk I/O (the warm-base accounting Eq. 7 staging relies
    on). Composed delta layers are cached under the delta file's path.

    Two opt-in compression layers shrink the stored zoo without changing
    what any read returns:

    - ``compress_deltas=True``: fine-tune residuals are stored sparse
      (CSR index+value, exact) when few entries changed, or int8/int16
      quantized (``quant_dtype``) when dense — whichever is smallest;
      raw wins ties so integer deltas and adversarial floats stay
      bit-exact. Every compressed file declares its max abs
      reconstruction error (0 for sparse/integer payloads,
      ``scale/2`` for quantized ones), surfaced as the
      ``quant_error_bound`` stats gauge.
    - ``dedup_pages=True``: plain (non-delta) layer payloads are chunked
      into content-hashed pages in a refcounted :class:`PageStore`
      (``_pages/`` beside the model dirs), so identical trunk pages
      across models store once. ``save``/``delete`` manage refcounts;
      :meth:`vacuum` collects orphaned pages.

    Both compose transparently through every read path — width slices,
    base+delta composition, chained fine-tunes, the layer LRU, pinning.
    """

    def __init__(self, root: Path, catalog: Optional[Catalog] = None,
                 cache_layers: bool = True,
                 cache_capacity_bytes: int = 256 << 20,
                 compress_deltas: bool = False,
                 quant_dtype: str = "int8",
                 sparse_eps: float = 0.0,
                 dedup_pages: bool = False,
                 page_bytes: int = 64 << 10):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.catalog = catalog or Catalog(self.root / "_catalog")
        self.cache_layers = cache_layers
        if quant_dtype not in ("int8", "int16"):
            raise ValueError(f"quant_dtype must be int8|int16, "
                             f"got {quant_dtype!r}")
        self.compress_deltas = bool(compress_deltas)
        self.quant_dtype = quant_dtype
        self.sparse_eps = float(sparse_eps)
        self.dedup_pages = bool(dedup_pages)
        self.page_bytes = int(page_bytes)
        self._page_store: Optional[PageStore] = None
        # byte-capped LRU: a long-lived session resolving many models
        # (a delta fleet's composed trunks, analytics over a wide zoo)
        # must not grow the cross-model tensor cache without bound.
        # Insertion order == recency order (moved-to-end on hit).
        self.cache_capacity_bytes = int(cache_capacity_bytes)
        self._layer_cache: "OrderedDict[Tuple[str, Optional[Tuple[int, int]]], np.ndarray]" = OrderedDict()
        self._cache_lock = threading.Lock()
        # trunk pinning (serving integration): refcounted file paths the
        # LRU must evict around — an active embed lane's trunk would be
        # re-read immediately, so evicting it only adds disk churn
        self._pin_count: Dict[str, int] = {}      # model_id -> pins
        self._pin_paths: Dict[str, List[str]] = {}  # model_id -> files
        self._pinned_paths: Dict[str, int] = {}   # file path -> refcount
        self.stats = StoreStats()

    def _dir(self, model_id: str) -> Path:
        return self.root / model_id

    @property
    def pages(self) -> PageStore:
        """The shared page store (created on first use; an existing
        ``_pages/`` dir is picked up even when ``dedup_pages`` is off,
        so a reader store can resolve paged layers a writer produced)."""
        if self._page_store is None:
            self._page_store = PageStore(self.root / "_pages",
                                         self.page_bytes)
        return self._page_store

    def _encode_delta(self, delta: np.ndarray) -> Tuple[bytes, str, float]:
        """Pick the smallest encoding for a fine-tune residual:
        raw dense, sparse (exact for eps=0 / integers), or quantized
        (floats only, finite only). Raw wins ties, so compression never
        costs bytes and never loses exactness without winning space.
        Returns ``(mvec_bytes, encoding, declared_bound)``."""
        n, item = delta.size, delta.itemsize
        dense_cost = n * item
        kind = delta.dtype.kind
        eps = self.sparse_eps if kind == "f" else 0.0
        if eps and kind == "f":
            nnz = int(np.count_nonzero(np.abs(delta) > eps))
        else:
            nnz = int(np.count_nonzero(delta))
        best = ("dense", dense_cost)
        sparse_cost = 16 + nnz * (8 + item)
        if sparse_cost < best[1]:
            best = ("sparse", sparse_cost)
        can_quant = (kind == "f" and n > 0
                     and bool(np.isfinite(delta).all()))
        if can_quant:
            code_item = 1 if self.quant_dtype == "int8" else 2
            quant_cost = 28 + n * code_item
            if quant_cost < best[1]:
                best = ("quant", quant_cost)
        if best[0] == "sparse":
            buf = mvec.encode_sparse(delta, flags=mvec.FLAG_DELTA, eps=eps)
            return buf, "sparse", float(eps)
        if best[0] == "quant":
            buf = mvec.encode_quant(delta, self.quant_dtype,
                                    flags=mvec.FLAG_DELTA)
            return buf, "quant", mvec.decode_aux(buf).bound
        return mvec.encode(delta, flags=mvec.FLAG_DELTA), "dense", 0.0

    def _decref_model_pages(self, model_id: str) -> None:
        """Drop page references held by a model's current layer files
        (before a re-save overwrites them, or a delete removes them)."""
        for li in self.catalog.get_layers(model_id):
            if li.file.startswith("@"):
                continue
            path = self._dir(model_id) / li.file
            if not path.exists():
                continue
            try:
                with open(path, "rb") as f:
                    head, aux = mvec.read_aux(f)
            except (ValueError, struct.error):
                continue
            if head.is_paged:
                self.pages.decref(aux.digests)

    def save(self, model_id: str, arch_meta: dict, params,
             base_model: Optional[str] = None,
             task_types: Optional[List[str]] = None,
             modality: str = "text") -> Path:
        """Save params as layer tables. With ``base_model``, only layers
        that differ from the base are written (delta storage)."""
        d = self._dir(model_id)
        d.mkdir(parents=True, exist_ok=True)
        # rewritten layer files invalidate caches — including composed
        # tensors of fine-tunes whose deltas reference this model
        # (transitively: a re-saved base stales every variant chain)
        stale, frontier = {model_id}, [model_id]
        while frontier:
            cur = frontier.pop()
            for info in self.catalog.list_models():
                if info.base_model == cur and info.model_id not in stale:
                    stale.add(info.model_id)
                    frontier.append(info.model_id)
        # separator suffix: 'm1' must not evict 'm10'
        prefixes = tuple(str(self._dir(m)) + os.sep for m in stale)
        with self._cache_lock:
            for k in [k for k in self._layer_cache
                      if k[0].startswith(prefixes)]:
                self.stats.cache_bytes -= self._layer_cache.pop(k).nbytes
        # re-save under the same id: release page references held by the
        # files about to be overwritten, and clear the old layer files so
        # a save with fewer layers leaves no unreachable garbage behind
        old_layers = self.catalog.get_layers(model_id)
        if old_layers:
            self._decref_model_pages(model_id)
            for li in old_layers:
                if not li.file.startswith("@"):
                    (d / li.file).unlink(missing_ok=True)
        (d / "architecture.json").write_text(json.dumps(arch_meta, indent=1))
        flat = flatten_params(params)
        base_flat: Dict[str, Any] = {}
        if base_model:
            base_flat = {li.layer_name: li
                         for li in self.catalog.get_layers(base_model)}
        layers: List[LayerInfo] = []
        for i, (key, leaf) in enumerate(sorted(flat.items())):
            arr = np.asarray(leaf)
            if base_model and key in base_flat:
                base_arr = np.asarray(
                    self._read_layer_file(base_model, base_flat[key]))
                if (base_arr.shape == arr.shape
                        and base_arr.tobytes() == arr.tobytes()):
                    # unchanged: reference the base *layer* (resolved
                    # through the catalog at read time, so chains —
                    # references to references, or to layers the base
                    # itself stores as deltas — stay correct), and
                    # write nothing
                    layers.append(LayerInfo(
                        model_id=model_id, layer_name=key, layer_index=i,
                        dtype=str(arr.dtype), shape=list(arr.shape),
                        nbytes=arr.nbytes,
                        file=f"@{base_model}:{key}",
                        delta_of=base_model))
                    continue
                if (base_arr.shape == arr.shape
                        and base_arr.dtype == arr.dtype
                        and arr.dtype.kind in "fiu"):
                    # changed, same geometry: store only the per-layer
                    # delta; reads compose base + delta (integers exact
                    # via wraparound, floats within 1 ulp — or within
                    # the declared bound when compression quantizes)
                    with np.errstate(over="ignore"):
                        delta = arr - base_arr
                    if self.compress_deltas:
                        buf, enc, bound = self._encode_delta(delta)
                    else:
                        buf = mvec.encode(delta, flags=mvec.FLAG_DELTA)
                        enc, bound = "dense", 0.0
                    fname = f"layer_{i:05d}.delta.mvec"
                    (d / fname).write_bytes(buf)
                    if enc != "dense":
                        self.stats.compressed_delta_bytes += len(buf)
                        self.stats.quant_error_bound = max(
                            self.stats.quant_error_bound, bound)
                    layers.append(LayerInfo(
                        model_id=model_id, layer_name=key, layer_index=i,
                        dtype=str(arr.dtype), shape=list(arr.shape),
                        nbytes=arr.nbytes, file=fname,
                        delta_of=base_model, enc=enc, bound=bound))
                    continue
            fname = f"layer_{i:05d}.mvec"
            enc = "dense"
            if self.dedup_pages:
                payload, pname = mvec.payload_array(arr)
                digests, dup_pages, dup_bytes = self.pages.put(
                    payload.tobytes())
                (d / fname).write_bytes(mvec.encode_paged(
                    pname, payload.shape, self.pages.page_bytes, digests))
                self.stats.dedup_pages += dup_pages
                self.stats.dedup_bytes_saved += dup_bytes
                enc = "paged"
            else:
                (d / fname).write_bytes(mvec.encode(arr))
            layers.append(LayerInfo(
                model_id=model_id, layer_name=key, layer_index=i,
                dtype=str(arr.dtype), shape=list(arr.shape),
                nbytes=arr.nbytes, file=fname, delta_of=None, enc=enc))
        self.catalog.register_layers(model_id, layers)
        # save generation: rewriting a model's files under the same id
        # must change every identity derived from them (trunk
        # fingerprints key share-cache entries and staged device
        # weights, which would otherwise serve the old tensors)
        try:
            gen = int(self.catalog.get_model(model_id)
                      .extra.get("save_gen", 0)) + 1
        except KeyError:
            gen = 1
        self.catalog.register_model(ModelInfo(
            model_id=model_id, storage="decoupled", path=str(d),
            base_model=base_model, task_types=task_types or [],
            modality=modality,
            param_count=int(sum(np.asarray(v).size
                                for v in flat.values())),
            extra={"save_gen": gen}))
        return d

    def _ref_target(self, li: LayerInfo
                    ) -> Optional[Tuple[str, LayerInfo]]:
        """Resolve an unchanged-layer reference one hop: ``@model:layer``
        points at the base model's *layer* (looked up in the catalog, so
        chained fine-tunes — references to references, or to layers the
        base itself stores as deltas — compose correctly); the legacy
        ``@model/file`` form references a concrete plain file (pre-delta
        stores never wrote anything else)."""
        if not li.file.startswith("@"):
            return None
        ref = li.file[1:]
        if ":" in ref:
            ref_model, ref_layer = ref.split(":", 1)
            target = next((b for b in self.catalog.get_layers(ref_model)
                           if b.layer_name == ref_layer), None)
            if target is None:
                raise KeyError(
                    f"layer {li.layer_name!r} of {li.model_id!r} "
                    f"references missing layer {ref_layer!r} in "
                    f"{ref_model!r}")
            return ref_model, target
        ref_model, ref_file = ref.split("/", 1)
        return ref_model, dc_replace(li, model_id=ref_model,
                                     file=ref_file, delta_of=None)

    def _resolve_layer(self, model_id: str,
                       li: LayerInfo) -> Tuple[str, LayerInfo]:
        """Follow the reference chain to the (owner model, layer) that
        actually defines a layer's content."""
        ref = self._ref_target(li)
        while ref is not None:
            model_id, li = ref
            ref = self._ref_target(li)
        return model_id, li

    def _resolve_layer_path(self, model_id: str, li: LayerInfo) -> Path:
        """Concrete file that defines a layer's content: references
        follow the chain to the defining model; a composed delta layer
        resolves to its delta file (the composed tensor really is a
        different tensor — that is what makes ``trunk_fingerprint``
        separate trunk-delta variants while inherited trunks share)."""
        owner, li = self._resolve_layer(model_id, li)
        return self._dir(owner) / li.file

    def _save_gen(self, model_id: str) -> int:
        try:
            return int(self.catalog.get_model(model_id)
                       .extra.get("save_gen", 0))
        except KeyError:
            return 0

    def _layer_ident(self, model_id: str, li: LayerInfo) -> str:
        """Content identity of a layer: the defining file's path plus
        the save generation of *every* model contributing to the
        tensor. A composed delta depends on its base chain too — a
        re-saved base must change the variant's identity even though
        the delta file itself is untouched."""
        ref = self._ref_target(li)
        if ref is not None:
            return self._layer_ident(*ref)
        ident = f"{self._dir(model_id) / li.file}@g{self._save_gen(model_id)}"
        if self._is_composed_delta(li):
            base_li = next(
                (b for b in self.catalog.get_layers(li.delta_of)
                 if b.layer_name == li.layer_name), None)
            if base_li is not None:
                ident += "+" + self._layer_ident(li.delta_of, base_li)
        return ident

    @staticmethod
    def _is_composed_delta(li: LayerInfo) -> bool:
        # delta_of + "@" file = unchanged reference (read base's layer);
        # delta_of + own file = stored delta tensor (compose base + delta)
        return li.delta_of is not None and not li.file.startswith("@")

    # -- trunk pinning + delta-aware eviction ------------------------------
    def _layer_paths(self, model_id: str, li: LayerInfo) -> List[str]:
        """Every concrete file a layer read touches: references follow
        the chain to the defining file; a composed delta needs its delta
        file *and* the base layer's files (composition re-reads both)."""
        ref = self._ref_target(li)
        if ref is not None:
            return self._layer_paths(*ref)
        out = [str(self._dir(model_id) / li.file)]
        if self._is_composed_delta(li):
            base_li = next(
                (b for b in self.catalog.get_layers(li.delta_of)
                 if b.layer_name == li.layer_name), None)
            if base_li is not None:
                out += self._layer_paths(li.delta_of, base_li)
        return out

    def pin_model(self, model_id: str, prefix: str = "trunk/") -> None:
        """Pin a model's trunk layers (resolved through references and
        delta composition, so a fine-tune pins the base files it
        actually reads) against layer-cache eviction. Refcounted: every
        ``pin_model`` needs a matching :meth:`unpin_model`. Raises
        KeyError for a model the catalog doesn't know."""
        self.catalog.get_model(model_id)          # KeyError if unknown
        with self._cache_lock:
            if model_id in self._pin_count:
                self._pin_count[model_id] += 1
                return
            paths = sorted({
                p for li in self.catalog.get_layers(model_id)
                if li.layer_name.startswith(prefix)
                for p in self._layer_paths(model_id, li)})
            self._pin_count[model_id] = 1
            self._pin_paths[model_id] = paths
            for p in paths:
                self._pinned_paths[p] = self._pinned_paths.get(p, 0) + 1

    def unpin_model(self, model_id: str) -> None:
        """Release one :meth:`pin_model` reference (no-op when the model
        isn't pinned — a stop path may race a never-started lane)."""
        with self._cache_lock:
            if model_id not in self._pin_count:
                return
            self._pin_count[model_id] -= 1
            if self._pin_count[model_id] > 0:
                return
            del self._pin_count[model_id]
            for p in self._pin_paths.pop(model_id, []):
                left = self._pinned_paths.get(p, 0) - 1
                if left > 0:
                    self._pinned_paths[p] = left
                else:
                    self._pinned_paths.pop(p, None)

    def _is_pinned(self, path_str: str) -> bool:
        return self._pinned_paths.get(path_str, 0) > 0

    def _chain_members(self, model_id: str) -> set:
        """The model plus every fine-tune whose base chain passes
        through it — the entries whose cached tensors depend on this
        model's files (the same traversal ``save`` uses to invalidate
        stale composed tensors)."""
        out, frontier = {model_id}, [model_id]
        while frontier:
            cur = frontier.pop()
            for info in self.catalog.list_models():
                if info.base_model == cur and info.model_id not in out:
                    out.add(info.model_id)
                    frontier.append(info.model_id)
        return out

    def _evict_chain_locked(self, victim_key) -> None:
        """Evict a victim together with every unpinned cached tensor of
        its delta chain (the victim's model + dependents composing
        against it): once part of a chain's files must be re-read, keeping
        the dependents' fragments only splits the chain's residency."""
        owners = self._chain_members(Path(victim_key[0]).parent.name)
        dirs = tuple(str(self._dir(m)) + os.sep for m in owners)
        for k in [k for k in self._layer_cache
                  if k == victim_key
                  or (k[0].startswith(dirs) and not self._is_pinned(k[0]))]:
            arr = self._layer_cache.pop(k)
            self.stats.cache_bytes -= arr.nbytes
            self.stats.cache_evictions += 1
            self.stats.cache_evicted_bytes += arr.nbytes

    def _cache_get(self, key):
        if not self.cache_layers:
            return None
        with self._cache_lock:
            cached = self._layer_cache.get(key)
            if cached is not None:
                self._layer_cache.move_to_end(key)   # freshen LRU order
        if cached is not None:
            self.stats.cache_hits += 1
            self.stats.cache_hit_bytes += cached.nbytes
        return cached

    def _cache_put(self, key, arr) -> None:
        if not self.cache_layers:
            return
        nbytes = int(np.asarray(arr).nbytes)
        cap = self.cache_capacity_bytes
        if nbytes > cap:
            return          # a tensor bigger than the cache never enters
        with self._cache_lock:
            old = self._layer_cache.pop(key, None)
            if old is not None:
                self.stats.cache_bytes -= old.nbytes
            self._layer_cache[key] = arr
            self.stats.cache_bytes += nbytes
            while self.stats.cache_bytes > cap and self._layer_cache:
                # LRU victim selection skips pinned trunks (files an
                # active serving lane holds); the victim's whole delta
                # chain leaves with it
                victim_key = next(
                    (k for k in self._layer_cache
                     if not self._is_pinned(k[0])), None)
                if victim_key is None:
                    break       # everything resident is pinned: stay over
                self._evict_chain_locked(victim_key)

    def _read_layer_file(self, model_id: str, li: LayerInfo,
                         rows: Optional[Tuple[int, int]] = None):
        ref = self._ref_target(li)
        if ref is not None:              # unchanged layer: read the
            return self._read_layer_file(*ref, rows=rows)  # base's
        if self._is_composed_delta(li):
            return self._read_delta_layer(model_id, li, rows)
        path = self._dir(model_id) / li.file
        key = (str(path), rows)
        cached = self._cache_get(key)
        if cached is not None:
            return cached
        with open(path, "rb") as f:
            head = mvec.read_header(f)
            if head.is_delta:
                raise ValueError(
                    f"{path} holds a FLAG_DELTA payload but is "
                    "catalogued as plain weights")
            if head.is_paged:
                arr, nread = self._read_paged(path, rows)
                self.stats.loaded_bytes += nread
            elif rows is not None:
                arr, nread, _aux = mvec.read_slice_counted(
                    f, rows[0], rows[1])
                self.stats.loaded_bytes += nread
            else:
                buf = f.read()
                arr = mvec.decode(buf)
                self.stats.loaded_bytes += len(buf)
        self._cache_put(key, arr)
        return arr

    def _read_paged(self, path: Path,
                    rows: Optional[Tuple[int, int]] = None
                    ) -> Tuple[np.ndarray, int]:
        """Materialize a paged layer (or a row range of it) from the
        page store, reading only the table plus the pages that overlap
        the requested byte range — paging preserves the partial-load
        property at page granularity."""
        buf = path.read_bytes()
        h = mvec.decode_header(buf)
        aux = mvec.decode_aux(buf)
        nread = len(buf)
        row_bytes = h.itemsize
        for dim in h.shape[1:]:
            row_bytes *= dim
        if rows is None:
            lo, hi = 0, h.nbytes
            out_shape = h.shape
        else:
            start = min(max(0, rows[0]), h.shape[0])
            stop = min(max(rows[1], start), h.shape[0])
            lo, hi = start * row_bytes, stop * row_bytes
            out_shape = (stop - start,) + h.shape[1:]
        pb = aux.page_bytes
        p0 = lo // pb if pb else 0
        p1 = -(-hi // pb) if pb else 0
        data = b"".join(self.pages.read_page(dg)
                        for dg in aux.digests[p0:p1])
        nread += len(data)
        raw = data[lo - p0 * pb:hi - p0 * pb]
        arr = np.frombuffer(raw, dtype=np.dtype(
            {"bfloat16": np.uint16}.get(h.dtype, h.dtype))
        ).reshape(out_shape)
        if h.dtype == "bfloat16":
            try:
                import ml_dtypes
                arr = arr.view(ml_dtypes.bfloat16)
            except ImportError:  # pragma: no cover
                pass
        return arr, nread

    def _read_delta_layer(self, model_id: str, li: LayerInfo,
                          rows: Optional[Tuple[int, int]] = None):
        """Compose ``base + delta`` for a fine-tune layer stored as a
        delta tensor. The base layer goes through :meth:`_read_layer_file`
        (so a warm base costs cache bytes, not disk bytes — only the
        delta's bytes count as loaded), and row-range reads slice base
        and delta identically, keeping width-sliced partial loads valid
        for deltas. The composed tensor is cached under the delta file's
        path; ``save`` invalidates it when base or variant is rewritten."""
        path = self._dir(model_id) / li.file
        key = (str(path), rows)
        cached = self._cache_get(key)
        if cached is not None:
            return cached
        base_li = next(
            (b for b in self.catalog.get_layers(li.delta_of)
             if b.layer_name == li.layer_name), None)
        if base_li is None:
            raise KeyError(
                f"delta layer {li.layer_name!r} of {model_id!r} references "
                f"missing base layer in {li.delta_of!r}")
        base_arr = np.asarray(
            self._read_layer_file(li.delta_of, base_li, rows=rows))
        with open(path, "rb") as f:
            head = mvec.read_header(f)
            if not head.is_delta:
                raise ValueError(
                    f"{path} is catalogued as a delta of {li.delta_of!r} "
                    "but its Mvec header lacks FLAG_DELTA")
            if rows is not None:
                delta, nread, aux = mvec.read_slice_counted(
                    f, rows[0], rows[1])
            else:
                buf = f.read()
                delta = mvec.decode(buf)
                nread = len(buf)
                aux = mvec.decode_aux(buf)
        self.stats.loaded_bytes += nread
        self.stats.delta_bytes += nread
        self.stats.delta_composes += 1
        if aux.bound:
            self.stats.quant_error_bound = max(
                self.stats.quant_error_bound, aux.bound)
        with np.errstate(over="ignore"):
            arr = base_arr + delta
        self._cache_put(key, arr)
        return arr

    def load(self, model_id: str, template=None,
             layer_filter: Optional[Callable[[str], bool]] = None):
        """Full or partial load. ``layer_filter(name)`` selects layers."""
        arch = json.loads((self._dir(model_id) / "architecture.json")
                          .read_text())
        self.stats.loads += 1
        if layer_filter is not None:
            self.stats.partial_loads += 1
        flat = {}
        for li in self.catalog.get_layers(model_id):
            if layer_filter and not layer_filter(li.layer_name):
                continue
            flat[li.layer_name] = self._read_layer_file(model_id, li)
        if template is not None and layer_filter is None:
            return arch, unflatten_like(template, flat)
        return arch, flat

    def load_layer_rows(self, model_id: str, layer_name: str,
                        start: int, stop: int):
        """Range read within one layer (per-shard restore / width-sliced
        trunk path): only the requested rows' bytes leave the disk."""
        for li in self.catalog.get_layers(model_id):
            if li.layer_name == layer_name:
                self.stats.loads += 1
                self.stats.partial_loads += 1
                return self._read_layer_file(model_id, li, rows=(start, stop))
        raise KeyError(layer_name)

    def trunk_fingerprint(self, model_id: str,
                          prefix: str = "trunk/") -> str:
        """Identity of a model's trunk: the *resolved* file paths of its
        trunk layers — the same key the layer-tensor cache uses, so two
        models whose fine-tune deltas reference one base trunk (or two
        tasks resolving to the same stored model) fingerprint equal and
        can share a serving embed lane. Paths are bound to their layer
        names (the same file set wired to different layers is a
        different trunk) and to the save generation of every
        contributing model (``_layer_ident``), so re-saving a model —
        or the base a delta composes against — changes the fingerprint
        instead of silently serving stale share-cache embeddings and
        staged weights."""
        pairs = sorted(
            (li.layer_name, self._layer_ident(model_id, li))
            for li in self.catalog.get_layers(model_id)
            if li.layer_name.startswith(prefix))
        if not pairs:
            return model_id
        digest = hashlib.sha1(
            "|".join(f"{n}={p}" for n, p in pairs).encode()
        ).hexdigest()[:16]
        return f"trunk:{digest}"

    def _file_stored_bytes(self, path: Path) -> int:
        """Disk bytes a layer file accounts for: its own size, plus its
        referenced pages for a paged table (a page shared with another
        model is attributed to both — per-model sums overstate shared
        storage; :meth:`disk_footprint` is the deduplicated truth)."""
        size = path.stat().st_size
        try:
            with open(path, "rb") as f:
                head, aux = mvec.read_aux(f)
        except (ValueError, struct.error):
            return size
        if head.is_paged:
            size += sum(self.pages.page_size_on_disk(dg)
                        for dg in aux.digests)
        return size

    def stored_bytes(self, model_id: str) -> int:
        """Actual new bytes on disk (referenced base layers count 0)."""
        total = 0
        for li in self.catalog.get_layers(model_id):
            if not li.file.startswith("@"):
                total += self._file_stored_bytes(
                    self._dir(model_id) / li.file)
        return total

    def delta_bytes(self, model_id: str) -> int:
        """Disk bytes of the model's fine-tune *delta* layers (0 for a
        base model): the marginal storage cost of the variant over its
        base — the 'K·delta' term in the fleet accounting
        ``base + K·delta`` that ``docs/benchmarks.md`` gates."""
        total = 0
        for li in self.catalog.get_layers(model_id):
            if self._is_composed_delta(li):
                total += (self._dir(model_id) / li.file).stat().st_size
        return total

    def cold_resolve_bytes(self, model_id: str) -> int:
        """Disk bytes a cold full load of the model reads: every unique
        concrete file its layers resolve through (delta chains include
        the base files the composition re-reads), with paged tables
        counting table + referenced pages. This is the compressed
        ``ModelSize`` the Eq. 7 host mem-read term should charge."""
        paths = sorted({p for li in self.catalog.get_layers(model_id)
                        for p in self._layer_paths(model_id, li)})
        return sum(self._file_stored_bytes(Path(p)) for p in paths)

    def disk_footprint(self) -> int:
        """Total bytes the store holds on disk — every model's layer
        files and architecture metadata plus the (deduplicated) page
        store. Shared pages count once, which is the whole point."""
        total = 0
        for info in self.catalog.list_models():
            d = self._dir(info.model_id)
            if not d.is_dir():
                continue
            total += sum(p.stat().st_size for p in d.iterdir()
                         if p.is_file())
        if (self.root / "_pages").is_dir():
            total += self.pages.total_bytes()
        return total

    def dependents(self, model_id: str) -> List[str]:
        """Models whose stored layers depend on this one: fine-tune
        lineage (``base_model``/``delta_of``) or direct ``@model:layer``
        / ``@model/file`` references."""
        out = set()
        for info in self.catalog.list_models():
            if info.model_id == model_id:
                continue
            if info.base_model == model_id:
                out.add(info.model_id)
                continue
            for li in self.catalog.get_layers(info.model_id):
                if (li.delta_of == model_id
                        or li.file.startswith(f"@{model_id}:")
                        or li.file.startswith(f"@{model_id}/")):
                    out.add(info.model_id)
                    break
        return sorted(out)

    def delete(self, model_id: str) -> None:
        """Drop a model: refuse while dependents still read through it
        (so a page or base layer reachable via ``'@model:layer'``
        references can never lose its owner), release its page
        references, evict its cached tensors, remove its files and
        catalog rows. Orphaned pages stay on disk until :meth:`vacuum`.
        """
        self.catalog.get_model(model_id)          # KeyError if unknown
        deps = self.dependents(model_id)
        if deps:
            raise ValueError(
                f"cannot delete {model_id!r}: referenced by {deps}")
        self._decref_model_pages(model_id)
        d = self._dir(model_id)
        prefix = str(d) + os.sep
        with self._cache_lock:
            for k in [k for k in self._layer_cache
                      if k[0].startswith(prefix)]:
                self.stats.cache_bytes -= self._layer_cache.pop(k).nbytes
            self._pin_count.pop(model_id, None)
            for p in self._pin_paths.pop(model_id, []):
                left = self._pinned_paths.get(p, 0) - 1
                if left > 0:
                    self._pinned_paths[p] = left
                else:
                    self._pinned_paths.pop(p, None)
        if d.is_dir():
            shutil.rmtree(d)
        self.catalog.drop_model(model_id)

    def vacuum(self) -> Tuple[int, int]:
        """GC orphaned tensor pages (refcount 0). Returns
        ``(pages_removed, bytes_freed)``; referenced pages — including
        ones reachable only through ``'@model:layer'`` chains, whose
        references :meth:`delete` refuses to orphan — are never
        collected."""
        if not (self.root / "_pages").is_dir():
            return 0, 0
        return self.pages.vacuum()


# ---------------------------------------------------------------------------
# API-based models (simulated remote endpoints)
# ---------------------------------------------------------------------------

class ApiModelRegistry:
    """External model endpoints as logical operators (paper §3.1).

    No real network in this environment: endpoints are callables with a
    latency model, retry/timeout logic, and a response cache — the same
    control surface the paper describes for remote closed-source models.
    """

    def __init__(self, catalog: Optional[Catalog] = None):
        self.catalog = catalog
        self._endpoints: Dict[str, dict] = {}
        self._cache: Dict[Tuple[str, bytes], Any] = {}
        self.stats: Dict[str, Dict[str, float]] = {}

    def register(self, model_id: str, fn: Callable, *,
                 url: str = "https://api.example/v1",
                 latency_s: float = 0.05, jitter_s: float = 0.0,
                 failure_rate: float = 0.0, quota: Optional[int] = None,
                 timeout_s: float = 1.0, max_retries: int = 3,
                 cache: bool = True) -> None:
        self._endpoints[model_id] = dict(
            fn=fn, url=url, latency_s=latency_s, jitter_s=jitter_s,
            failure_rate=failure_rate, quota=quota, used=0,
            timeout_s=timeout_s, max_retries=max_retries, cache=cache)
        self.stats[model_id] = {"calls": 0, "retries": 0, "cache_hits": 0,
                                "latency_total": 0.0}
        if self.catalog:
            self.catalog.register_model(ModelInfo(
                model_id=model_id, storage="api", path=url,
                extra={"latency_s": latency_s}))

    def invoke(self, model_id: str, payload, rng: Optional[np.random.Generator] = None):
        ep = self._endpoints[model_id]
        st = self.stats[model_id]
        rng = rng or np.random.default_rng(0)
        key = None
        if ep["cache"]:
            try:
                key = (model_id, pickle.dumps(np.asarray(payload)))
            except Exception:
                key = None
            if key is not None and key in self._cache:
                st["cache_hits"] += 1
                return self._cache[key]
        if ep["quota"] is not None and ep["used"] >= ep["quota"]:
            raise RuntimeError(f"quota exhausted for {model_id}")
        last_err = None
        for attempt in range(ep["max_retries"] + 1):
            st["calls"] += 1
            ep["used"] += 1
            lat = ep["latency_s"] + float(rng.random()) * ep["jitter_s"]
            if lat > ep["timeout_s"]:
                st["retries"] += 1
                last_err = TimeoutError(f"{model_id} timed out")
                continue
            if ep["failure_rate"] and float(rng.random()) < ep["failure_rate"]:
                st["retries"] += 1
                last_err = ConnectionError(f"{model_id} transient failure")
                continue
            st["latency_total"] += lat
            time.sleep(min(lat, 0.002))  # token sleep, keep tests fast
            out = ep["fn"](payload)
            if key is not None:
                self._cache[key] = out
            return out
        raise last_err or RuntimeError("unreachable")

    def expected_latency(self, model_id: str) -> float:
        return self._endpoints[model_id]["latency_s"]
