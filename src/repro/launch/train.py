"""Training launcher: config -> mesh -> sharded train loop with
checkpoint/restart, straggler monitoring, and metrics logging.

CPU-scale example (runs here):
  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --smoke \
      --steps 30 --batch 4 --seq 128
Production pods use the same entry point with --mesh single|multi.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import get_config, smoke_config
from repro.data import DataConfig, SyntheticCorpus
from repro.distributed.sharding import axis_rules, rules_for_config, tree_shardings
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import batch_axes, build_model
from repro.storage import CheckpointManager
from repro.training import (OptimizerConfig, init_state, make_train_step,
                            state_axes)
from repro.training.fault import StragglerMonitor, TrainController


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--mesh", choices=["none", "host", "single", "multi"],
                    default="none")
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg, attn_impl="naive" if args.smoke else "chunked")
    opt_cfg = OptimizerConfig(learning_rate=args.lr, warmup_steps=10,
                              total_steps=args.steps)
    step_fn = make_train_step(model, opt_cfg, accum_steps=args.accum)

    mesh = None
    if args.mesh == "host":
        n = len(jax.devices())
        mesh = make_host_mesh(max(1, n // 2), min(2, n))
    elif args.mesh in ("single", "multi"):
        mesh = make_production_mesh(multi_pod=args.mesh == "multi")

    rng = jax.random.PRNGKey(0)
    data = SyntheticCorpus(DataConfig(vocab_size=cfg.vocab_size,
                                      seq_len=args.seq,
                                      global_batch=args.batch))

    if mesh is not None:
        rules = rules_for_config(cfg)
        p_shard = tree_shardings(mesh, model.param_axes(), rules)
        o_shard = tree_shardings(mesh, state_axes(model.param_axes()), rules)
        b_shard = tree_shardings(mesh, batch_axes(cfg), rules)
        ctx = axis_rules(rules, mesh=mesh)
        jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None))
    else:
        import contextlib
        ctx = contextlib.nullcontext()
        jitted = jax.jit(step_fn)

    with ctx:
        params = model.init(rng)
        opt = init_state(params, opt_cfg.opt_dtype)
        ckpt = CheckpointManager(Path(args.ckpt_dir) / cfg.arch_id)

        losses = []

        def one_step(state, step):
            params, opt = state
            batch = {k: jax.numpy.asarray(v)
                     for k, v in data.batch(step).items()}
            params, opt, out = jitted(params, opt, batch)
            losses.append(float(out["loss"]))
            if step % args.log_every == 0:
                print(f"step {step}: loss={out['loss']:.4f} "
                      f"gnorm={out['grad_norm']:.3f} lr={out['lr']:.2e}")
            return (params, opt)

        controller = TrainController(one_step, ckpt,
                                     ckpt_every=args.ckpt_every,
                                     monitor=StragglerMonitor())
        t0 = time.time()
        (params, opt), step = controller.run((params, opt), args.steps)
        dt = time.time() - t0
    tokens = args.steps * args.batch * args.seq
    print(f"done: {step} steps in {dt:.1f}s "
          f"({tokens / dt:.0f} tok/s); loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
