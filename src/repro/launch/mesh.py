"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16x16 = 256 chips ("data","model");
multi-pod: 2x16x16 = 512 chips ("pod","data","model").
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh over host devices for multi-device tests."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto, AxisType.Auto))


def dp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]
