"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state). Single pod: 16x16 = 256 chips ("data","model");
multi-pod: 2x16x16 = 512 chips ("pod","data","model").
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:                     # jax >= 0.5 explicit axis types
    from jax.sharding import AxisType
except ImportError:      # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 4) -> Mesh:
    """Small mesh over host devices for multi-device tests."""
    return _make_mesh((data, model), ("data", "model"))


def make_serving_mesh(device_count: int) -> Mesh:
    """1-D ("data",) mesh over the first ``device_count`` devices — the
    mesh the serving backend pool's data-parallel embed lanes span."""
    import numpy as np
    avail = jax.devices()
    n = max(1, min(int(device_count), len(avail)))
    return Mesh(np.array(avail[:n]), ("data",))


def dp_size(mesh: Mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n


def tp_size(mesh: Mesh) -> int:
    return mesh.shape["model"]
