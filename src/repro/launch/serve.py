"""Serving launcher: prefill + continuous-batching decode engine.

CPU-scale example (runs here):
  PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-1.8b \
      --smoke --requests 8 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.models import build_model
from repro.pipeline import OpProfile, choose_batch_size
from repro.training import make_serve_step


class ServingEngine:
    """Batched prefill+decode over a fixed-size slot pool (the serving
    side of the paper's window-function batch inference)."""

    def __init__(self, model, params, *, max_len: int, batch_slots: int):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.slots = batch_slots
        self.serve_step = jax.jit(make_serve_step(model))
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, max_len=max_len))

    def generate(self, prompts: np.ndarray, gen_tokens: int) -> np.ndarray:
        """prompts: [B, S] -> generated ids [B, gen_tokens] (greedy)."""
        B = prompts.shape[0]
        outs = []
        for lo in range(0, B, self.slots):
            chunk = prompts[lo:lo + self.slots]
            logits, state = self._prefill(self.params, jnp.asarray(chunk))
            tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            gen = [tok]
            for _ in range(gen_tokens - 1):
                tok, state = self.serve_step(self.params, state, tok)
                gen.append(tok)
            outs.append(jnp.concatenate(gen, axis=1))
        return np.asarray(jnp.concatenate(outs, axis=0))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.is_encoder_decoder:
        raise SystemExit("use examples/serve_encdec.py for enc-dec archs")
    model = build_model(cfg, attn_impl="naive" if args.smoke else "chunked")
    params = model.init(jax.random.PRNGKey(0))

    # cost-model batch size (Eq. 11) for the decode step
    n = cfg.param_count()
    prof = OpProfile(flops_per_row=2.0 * n, bytes_per_row=cfg.d_model * 2,
                     model_bytes=n * 2)
    slots = choose_batch_size(prof, "tpu", mem_cap_bytes=8e9,
                              candidates=(1, 2, 4, 8, 16, 32))
    print(f"serving {cfg.arch_id}: batch slots={slots} (cost model)")

    engine = ServingEngine(model, params, max_len=args.prompt_len + args.gen,
                           batch_slots=slots)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.requests, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = engine.generate(prompts, args.gen)
    dt = time.time() - t0
    total = args.requests * args.gen
    print(f"generated {out.shape} in {dt:.2f}s "
          f"({total / dt:.1f} tok/s); sample: {out[0][:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
