import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes, record memory/cost/collective analyses as JSON.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.analysis.hlo import model_flops, roofline_terms
from repro.analysis.hlo_cost import analyze_hlo
from repro.analysis.jaxpr_flops import count_flops
from repro.configs import SHAPES, get_config, list_archs, shapes_for
from repro.distributed.sharding import (axis_rules, rules_for_config,
                                        tree_shardings)
from repro.launch.mesh import dp_size, make_production_mesh
from repro.models import batch_axes, build_model, input_specs
from repro.training import (OptimizerConfig, abstract_state,
                            make_prefill_step, make_serve_step,
                            make_train_step, state_axes)

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

_BF16_OPT = {"llama3-405b", "kimi-k2-1t-a32b"}  # bf16 moments (HBM budget)


def _rule_overrides(cfg, shape, mesh):
    """Shape-aware rule tweaks (see DESIGN.md §6 and EXPERIMENTS.md §Perf).

    Decode shards the KV cache length over 'model' (flash-decode style);
    per-token q-head compute is tiny, so heads are replicated — sharding
    both would force an all-gather of the cache over 'model'.
    """
    ov = {}
    if shape.kind in ("train", "prefill") and cfg.seq_parallel:
        ov["residual_seq"] = ("model",)
    if shape.kind == "decode":
        ov["act_heads"] = None
        ov["act_kv_heads"] = None
        dp = dp_size(mesh)
        if shape.global_batch % dp != 0:  # long_500k: batch 1
            ov["batch"] = None
            ov["cache_seq"] = ("data", "model")
        else:
            ov["cache_seq"] = ("model",)
    return ov


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               opt_overrides=None, variant: str = "opt",
               rule_extra=None, cfg_overrides=None):
    """Build + lower + compile one cell; returns (record, compiled).

    variant='baseline' reproduces the paper-faithful naive implementation
    (f32-upcast decode, replicated KV length) for §Perf before/after.
    """
    from repro.models.attention import set_decode_f32_upcast
    from repro.models.moe import set_moe_bf16_collectives
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    tags = set(variant.split("+"))
    if "baseline" in tags:
        set_decode_f32_upcast(True)
        set_moe_bf16_collectives(False)
        overrides = {}  # naive: cache replicated over 'model'
    else:
        set_decode_f32_upcast(False)
        set_moe_bf16_collectives("bf16coll" in tags)
        overrides = _rule_overrides(cfg, shape, mesh)
        if "sp" in tags:  # sequence-parallel residual stream
            overrides["residual_seq"] = ("model",)
    if rule_extra:
        overrides.update(rule_extra)
    rules = rules_for_config(cfg, multi_pod=multi_pod, overrides=overrides)
    model = build_model(cfg)
    opt_cfg = OptimizerConfig(
        opt_dtype="bfloat16" if arch in _BF16_OPT else "float32")
    if opt_overrides:
        import dataclasses
        opt_cfg = dataclasses.replace(opt_cfg, **opt_overrides)

    aparams = model.abstract()
    p_shard = tree_shardings(mesh, model.param_axes(), rules)
    b_specs = input_specs(cfg, shape)
    b_shard = tree_shardings(mesh, batch_axes(cfg), rules)

    with axis_rules(rules, mesh=mesh):
        if shape.kind == "train":
            accum = min(cfg.grad_accum, max(1, shape.global_batch // dp_size(mesh)))
            step = make_train_step(model, opt_cfg, accum_steps=accum)
            aopt = abstract_state(aparams, opt_cfg.opt_dtype)
            o_shard = tree_shardings(mesh, state_axes(model.param_axes()),
                                     rules)
            jitted = jax.jit(step,
                             in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None))
            step_args = (aparams, aopt, b_specs)
        elif shape.kind == "prefill":
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard),
                             out_shardings=None)
            step_args = (aparams, b_specs)
        else:  # decode
            step = make_serve_step(model)
            B = shape.global_batch
            acache = jax.eval_shape(
                lambda: model.init_cache(B, shape.seq_len))
            c_shard = tree_shardings(mesh, model.cache_axes(), rules)
            tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
            t_shard = tree_shardings(mesh, {"t": ("batch", None)}, rules)["t"]
            jitted = jax.jit(step,
                             in_shardings=(p_shard, c_shard, t_shard),
                             out_shardings=(None, c_shard))
            step_args = (aparams, acache, tok)

        lowered = jitted.lower(*step_args)
        # exact GLOBAL matmul FLOPs from the jaxpr (scan x length,
        # ragged_dot = 2mkn, shard_map body x mesh size)
        jaxpr_flops = count_flops(jax.make_jaxpr(step)(*step_args))
        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):     # jax 0.4.x: list of one dict
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    mem_d = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        mem_d[f] = getattr(mem, f, None)
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)
    flops_pd = jaxpr_flops / chips
    bytes_pd = hc.bytes_accessed
    terms = roofline_terms(flops_pd, bytes_pd, hc.collective_operand_bytes)
    mf = model_flops(cfg, shape, per_device=True, chips=chips)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": chips,
        "compile_s": compile_s,
        "flops_per_device": flops_pd,
        "hlo_dot_flops_per_device": hc.dot_flops,
        "xla_cost_flops_loop_once": float(cost.get("flops", 0.0)),
        "bytes_accessed_per_device": bytes_pd,
        "xla_bytes_loop_once": float(cost.get("bytes accessed", 0.0)),
        "collectives": hc.to_dict(),
        "memory_analysis": mem_d,
        "roofline": terms,
        "model_flops_per_device": mf,
        "useful_flops_ratio": (mf / flops_pd) if flops_pd else None,
        "hlo_bytes": len(hlo),
        "loop_trip_counts": hc.loop_trip_counts[:32],
    }
    return rec, compiled


def run_cell(arch, shape_name, multi_pod, out_dir: Path, tag: str = ""):
    key = f"{arch}/{shape_name}/{'multi' if multi_pod else 'single'}"
    out = out_dir / ("multi" if multi_pod else "single") / arch
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{shape_name}{tag}.json"
    try:
        rec, compiled = lower_cell(arch, shape_name, multi_pod)
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in sorted(ca)[:6]} if ca else None)
        path.write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(f"OK  {key}: compute={r['compute_s']:.4f}s "
              f"memory={r['memory_s']:.4f}s coll={r['collective_s']:.4f}s "
              f"dominant={r['dominant']} "
              f"useful={rec['useful_flops_ratio'] and rec['useful_flops_ratio']:.3f} "
              f"(compile {rec['compile_s']:.0f}s)")
        return True
    except Exception as e:
        traceback.print_exc()
        path.with_suffix(".err").write_text(
            f"{type(e).__name__}: {e}\n{traceback.format_exc()}")
        print(f"FAIL {key}: {type(e).__name__}: {e}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    out_dir = Path(args.out)

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    archs = [args.arch] if args.arch else list_archs()
    for a in archs:
        cfg = get_config(a)
        shapes = ([args.shape] if args.shape
                  else [s.name for s in shapes_for(cfg)])
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))

    ok = fail = skip = 0
    for a, s, mp in cells:
        p = (out_dir / ("multi" if mp else "single") / a / f"{s}.json")
        if args.skip_existing and p.exists():
            skip += 1
            continue
        if run_cell(a, s, mp, out_dir):
            ok += 1
        else:
            fail += 1
    print(f"done: ok={ok} fail={fail} skipped={skip}")
    return 0 if fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
